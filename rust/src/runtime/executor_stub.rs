//! Stub PJRT engine for builds without the `pjrt` feature.
//!
//! The real [`super::executor`] (compiled with `--features pjrt`) drives
//! AOT-compiled HLO artifacts through the `xla` PJRT bindings, which do not
//! exist in the offline crate universe. This stub keeps the public API —
//! and therefore every caller (`experiments::fig9`, benches, examples,
//! integration tests) — compiling unchanged: [`PjrtEngine::new`] always
//! returns an error, which callers already treat as "accelerator
//! unavailable, fall back to the native engine".

use std::path::Path;

use anyhow::{bail, Result};

use super::artifact::ArtifactRegistry;
use crate::correction::PocsResult;

/// Placeholder for the PJRT-backed correction engine.
pub struct PjrtEngine {
    registry: ArtifactRegistry,
}

impl PjrtEngine {
    /// Always errors: PJRT support is not compiled in.
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let _ = artifact_dir;
        bail!(
            "PJRT support is not compiled in — rebuild with \
             `--features pjrt` and an available `xla` crate"
        );
    }

    /// Platform string of the underlying PJRT client.
    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }

    /// Does a compiled variant exist for this exact shape?
    pub fn supports_shape(&self, shape: &[usize]) -> bool {
        self.registry.find_exact(shape).is_some()
    }

    /// Unreachable in practice (the constructor always errors), but kept
    /// signature-compatible with the real engine.
    pub fn correct(
        &mut self,
        _eps0: &[f64],
        _shape: &[usize],
        _e_bound: f64,
        _d_bound: f64,
    ) -> Result<PocsResult> {
        bail!("PJRT support is not compiled in");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_constructor_errors() {
        assert!(PjrtEngine::new(Path::new("artifacts")).is_err());
    }
}
