//! PJRT execution of the AOT correction artifacts.
//!
//! [`PjrtEngine`] owns one CPU PJRT client and a cache of compiled
//! executables (compilation happens lazily on the first use of each
//! variant — the analogue of cuFFT plan creation + CUDA module load).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::artifact::{ArtifactRegistry, VariantMeta};
use crate::correction::PocsResult;
use crate::fourier::Complex;

/// Runs FFCz corrections through compiled HLO artifacts.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    registry: ArtifactRegistry,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtEngine {
    /// Create an engine over an artifact directory (must contain
    /// `manifest.txt`; build with `make artifacts`).
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let registry = ArtifactRegistry::load(artifact_dir)?;
        if registry.is_empty() {
            bail!("artifact registry at {} is empty", artifact_dir.display());
        }
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            registry,
            compiled: HashMap::new(),
        })
    }

    /// Platform string of the underlying PJRT client.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }

    /// Does a compiled variant exist for this exact shape?
    pub fn supports_shape(&self, shape: &[usize]) -> bool {
        self.registry.find_exact(shape).is_some()
    }

    fn ensure_compiled(&mut self, variant: &VariantMeta) -> Result<()> {
        if self.compiled.contains_key(&variant.name) {
            return Ok(());
        }
        let proto = xla::HloModuleProto::from_text_file(
            variant
                .path
                .to_str()
                .ok_or_else(|| anyhow::anyhow!("non-utf8 artifact path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", variant.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", variant.name))?;
        self.compiled.insert(variant.name.clone(), exe);
        Ok(())
    }

    /// Run the correction loop for an error vector whose shape exactly
    /// matches a compiled variant. Inputs/outputs are f64 on the Rust side
    /// and f32 inside the artifact (the paper's GPU kernels are f32 too).
    pub fn correct(
        &mut self,
        eps0: &[f64],
        shape: &[usize],
        e_bound: f64,
        d_bound: f64,
    ) -> Result<PocsResult> {
        let variant = self
            .registry
            .find_exact(shape)
            .ok_or_else(|| anyhow::anyhow!("no artifact variant for shape {shape:?}"))?
            .clone();
        self.ensure_compiled(&variant)?;
        let exe = self.compiled.get(&variant.name).unwrap();

        let eps_f32: Vec<f32> = eps0.iter().map(|&v| v as f32).collect();
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let eps_lit = xla::Literal::vec1(&eps_f32).reshape(&dims)?;
        let e_lit = xla::Literal::scalar(e_bound as f32);
        let d_lit = xla::Literal::scalar(d_bound as f32);

        let result = exe.execute::<xla::Literal>(&[eps_lit, e_lit, d_lit])?[0][0]
            .to_literal_sync()?;
        let outs = result.to_tuple()?;
        if outs.len() != 6 {
            bail!("artifact returned {} outputs, expected 6", outs.len());
        }
        let corrected: Vec<f32> = outs[0].to_vec()?;
        let spat: Vec<f32> = outs[1].to_vec()?;
        let f_re: Vec<f32> = outs[2].to_vec()?;
        let f_im: Vec<f32> = outs[3].to_vec()?;
        let iterations: i32 = outs[4].get_first_element()?;
        // `converged` lowers as pred; convert to S32 for extraction (the
        // crate's typed accessors reject PRED directly).
        let converged = outs[5]
            .convert(xla::PrimitiveType::S32)
            .and_then(|l| l.get_first_element::<i32>())
            .map(|v| v != 0)
            .unwrap_or(false);

        let spat_edits: Vec<f64> = spat.iter().map(|&v| v as f64).collect();
        let freq_edits: Vec<Complex> = f_re
            .iter()
            .zip(&f_im)
            .map(|(&r, &i)| Complex::new(r as f64, i as f64))
            .collect();
        let active_spat = spat_edits.iter().filter(|&&v| v != 0.0).count();
        let active_freq = freq_edits
            .iter()
            .filter(|c| c.re != 0.0 || c.im != 0.0)
            .count();
        Ok(PocsResult {
            corrected_eps: corrected.iter().map(|&v| v as f64).collect(),
            spat_edits,
            // Hermitian *projection* (like the native engines): the f32
            // artifact's mirror bins match the stored bins only up to f32
            // rounding, and only the Hermitian part of the edits reaches
            // the real ε — folding keeps the edits-reconstruct invariant.
            freq_edits: crate::fourier::HalfSpectrum::fold_full(&freq_edits, shape),
            iterations: iterations.max(0) as usize,
            converged,
            active_spat,
            active_freq,
        })
    }
}

// Integration tests live in rust/tests/pjrt_engine.rs (they need built
// artifacts); unit tests here cover only artifact-independent pieces.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_requires_manifest() {
        assert!(PjrtEngine::new(Path::new("/definitely/missing")).is_err());
    }
}
