//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas correction
//! artifacts (`artifacts/*.hlo.txt`) from the Rust hot path.
//!
//! Wiring (see /opt/xla-example/load_hlo for the reference pattern):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`.
//!
//! The [`executor::PjrtEngine`] is the "accelerator path" of the
//! coordinator — the analogue of the paper's GPU implementation — while
//! `correction::pocs` is the native CPU baseline. Both implement the same
//! loop semantics, letting experiments compare engines (paper Table IV /
//! Fig. 9).

pub mod artifact;
// The real executor needs the `xla` PJRT bindings, which are absent from
// the offline crate universe. Default builds get an API-identical stub
// whose constructor errors; enable the `pjrt` cargo feature (and provide
// an `xla` crate) for the real engine.
#[cfg(feature = "pjrt")]
pub mod executor;
#[cfg(not(feature = "pjrt"))]
#[path = "executor_stub.rs"]
pub mod executor;

pub use artifact::{ArtifactRegistry, VariantMeta};
pub use executor::PjrtEngine;

/// Default artifact directory relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";
