//! Artifact registry: discovers AOT variants from `artifacts/manifest.txt`
//! (the line-based twin of manifest.json emitted by `python/compile/aot.py`;
//! the offline crate set has no JSON parser).
//!
//! Format, one variant per line: `name|dim0,dim1,…|max_iters|file`.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One AOT-lowered correction variant.
#[derive(Debug, Clone, PartialEq)]
pub struct VariantMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub max_iters: usize,
    /// Absolute path of the HLO text file.
    pub path: PathBuf,
}

impl VariantMeta {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

/// The set of variants available in an artifact directory.
#[derive(Debug, Clone, Default)]
pub struct ArtifactRegistry {
    variants: Vec<VariantMeta>,
}

impl ArtifactRegistry {
    /// Load the registry from `dir/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {}", manifest.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text; file paths resolve relative to `dir`.
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut variants = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split('|').collect();
            if parts.len() != 4 {
                bail!("manifest line {}: expected 4 fields", lineno + 1);
            }
            let shape: Vec<usize> = parts[1]
                .split(',')
                .map(|s| s.trim().parse::<usize>())
                .collect::<Result<_, _>>()
                .with_context(|| format!("manifest line {}: bad shape", lineno + 1))?;
            let max_iters: usize = parts[2]
                .trim()
                .parse()
                .with_context(|| format!("manifest line {}: bad max_iters", lineno + 1))?;
            variants.push(VariantMeta {
                name: parts[0].trim().to_string(),
                shape,
                max_iters,
                path: dir.join(parts[3].trim()),
            });
        }
        Ok(Self { variants })
    }

    pub fn variants(&self) -> &[VariantMeta] {
        &self.variants
    }

    pub fn is_empty(&self) -> bool {
        self.variants.is_empty()
    }

    /// Find the variant matching a shape exactly.
    pub fn find_exact(&self, shape: &[usize]) -> Option<&VariantMeta> {
        self.variants.iter().find(|v| v.shape == shape)
    }

    /// Find by name.
    pub fn find_name(&self, name: &str) -> Option<&VariantMeta> {
        self.variants.iter().find(|v| v.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
ffcz_correct_1d_4096|4096|64|ffcz_correct_1d_4096.hlo.txt
ffcz_correct_2d_64x64|64,64|64|ffcz_correct_2d_64x64.hlo.txt

ffcz_correct_3d_16|16,16,16|32|ffcz_correct_3d_16.hlo.txt
";

    #[test]
    fn parses_manifest() {
        let r = ArtifactRegistry::parse(SAMPLE, Path::new("/arts")).unwrap();
        assert_eq!(r.variants().len(), 3);
        let v = r.find_name("ffcz_correct_2d_64x64").unwrap();
        assert_eq!(v.shape, vec![64, 64]);
        assert_eq!(v.max_iters, 64);
        assert_eq!(v.path, Path::new("/arts/ffcz_correct_2d_64x64.hlo.txt"));
        assert_eq!(v.element_count(), 4096);
    }

    #[test]
    fn find_exact_matches_shape() {
        let r = ArtifactRegistry::parse(SAMPLE, Path::new("/a")).unwrap();
        assert!(r.find_exact(&[4096]).is_some());
        assert!(r.find_exact(&[16, 16, 16]).is_some());
        assert!(r.find_exact(&[64]).is_none());
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(ArtifactRegistry::parse("bad line", Path::new("/a")).is_err());
        assert!(ArtifactRegistry::parse("a|x,y|64|f", Path::new("/a")).is_err());
        assert!(ArtifactRegistry::parse("a|4|many|f", Path::new("/a")).is_err());
    }

    #[test]
    fn missing_manifest_errors() {
        assert!(ArtifactRegistry::load(Path::new("/nonexistent-dir-xyz")).is_err());
    }
}
