//! Compaction, quantization, and lossless coding of FFCz edits
//! (paper §IV-B "Compaction, quantization, and lossless compression").
//!
//! Each edit stream (spatial: real, frequency: complex) is stored as
//! * a bit-packed *flag* vector marking nonzero components,
//! * a *compact* vector of the nonzero values, quantized to `m`-bit
//!   integers on a uniform grid scaled to the stream's max magnitude,
//! * everything entropy-coded with canonical Huffman followed by ZSTD.
//!
//! Dequantization is exactly reproducible (grid index × step), so encoder
//! and decoder agree bit-for-bit on the applied edits — the encoder
//! verifies the dual bounds against the *dequantized* edits before
//! committing (see `correction::compress`).

use anyhow::{bail, Result};

use crate::encoding::{
    fixed, huffman_decode, huffman_encode, lossless_compress, lossless_decompress, pack_flags,
    unpack_flags, varint,
};
use crate::fourier::Complex;

/// Quantization code length in bits (paper fixes m = 16).
pub const QUANT_BITS: u32 = 16;
const QMAX: i64 = (1 << (QUANT_BITS - 1)) - 1; // 32767

/// A quantized sparse real-valued edit stream.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantizedEdits {
    /// Total length of the (dense) edit vector.
    pub n: usize,
    /// Quantization step (0 ⇒ stream is all-zero).
    pub step: f64,
    /// Indices of nonzero entries (ascending).
    pub idx: Vec<u32>,
    /// Quantized values at those indices (grid index, never 0).
    pub q: Vec<i32>,
}

impl QuantizedEdits {
    /// Quantize a dense edit vector. Values round to the nearest grid
    /// point; values that round to grid index 0 are dropped (their effect
    /// is below half a quantization step).
    pub fn quantize(edits: &[f64]) -> Self {
        let n = edits.len();
        let max_abs = edits.iter().fold(0.0f64, |a, &v| a.max(v.abs()));
        if max_abs == 0.0 {
            return Self {
                n,
                step: 0.0,
                idx: Vec::new(),
                q: Vec::new(),
            };
        }
        let step = max_abs / QMAX as f64;
        let mut idx = Vec::new();
        let mut q = Vec::new();
        for (i, &v) in edits.iter().enumerate() {
            if v == 0.0 {
                continue;
            }
            let g = (v / step).round() as i64;
            if g == 0 {
                continue;
            }
            idx.push(i as u32);
            q.push(g.clamp(-QMAX, QMAX) as i32);
        }
        Self { n, step, idx, q }
    }

    /// Reconstruct the dense edit vector.
    pub fn dequantize(&self) -> Vec<f64> {
        let mut out = vec![0.0f64; self.n];
        for (&i, &g) in self.idx.iter().zip(&self.q) {
            out[i as usize] = g as f64 * self.step;
        }
        out
    }

    /// Number of active (nonzero) edits.
    pub fn active(&self) -> usize {
        self.idx.len()
    }

    /// Serialize: flags (packed+zstd) + quantized values (huffman+zstd).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        varint::write(&mut out, self.n as u64);
        out.extend_from_slice(&self.step.to_le_bytes());
        varint::write(&mut out, self.idx.len() as u64);
        if self.idx.is_empty() {
            return out;
        }
        // Flags.
        let mut flags = vec![false; self.n];
        for &i in &self.idx {
            flags[i as usize] = true;
        }
        let enc_flags = lossless_compress(&pack_flags(&flags));
        varint::write(&mut out, enc_flags.len() as u64);
        out.extend_from_slice(&enc_flags);
        // Values: map i32 grid index to u16 symbols via zigzag (fits by
        // construction: |g| ≤ 32767 ⇒ zigzag < 65536).
        let syms: Vec<u16> = self.q.iter().map(|&g| varint::zigzag(g as i64) as u16).collect();
        let enc_vals = lossless_compress(&huffman_encode(&syms));
        varint::write(&mut out, enc_vals.len() as u64);
        out.extend_from_slice(&enc_vals);
        out
    }

    /// Inverse of [`QuantizedEdits::to_bytes`].
    pub fn from_bytes(buf: &[u8], pos: &mut usize) -> Result<Self> {
        let n = varint::read(buf, pos)? as usize;
        let step = fixed::read_f64_le(buf, pos, "edit stream quantization step")?;
        let count = varint::read(buf, pos)? as usize;
        if count == 0 {
            return Ok(Self {
                n,
                step,
                idx: Vec::new(),
                q: Vec::new(),
            });
        }
        let flen = varint::read(buf, pos)? as usize;
        if *pos + flen > buf.len() {
            bail!("truncated flag section");
        }
        let packed = lossless_decompress(&buf[*pos..*pos + flen])?;
        *pos += flen;
        let flags = unpack_flags(&packed, n);
        let idx: Vec<u32> = flags
            .iter()
            .enumerate()
            .filter(|(_, &f)| f)
            .map(|(i, _)| i as u32)
            .collect();
        if idx.len() != count {
            bail!("flag count {} != stored count {}", idx.len(), count);
        }
        let vlen = varint::read(buf, pos)? as usize;
        if *pos + vlen > buf.len() {
            bail!("truncated value section");
        }
        let syms = huffman_decode(&lossless_decompress(&buf[*pos..*pos + vlen])?, count)?;
        *pos += vlen;
        let q: Vec<i32> = syms
            .into_iter()
            .map(|s| varint::unzigzag(s as u64) as i32)
            .collect();
        Ok(Self { n, step, idx, q })
    }
}

/// Quantized complex (frequency-domain) edit stream: shared flags, two
/// value planes.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantizedComplexEdits {
    pub re: QuantizedEdits,
    pub im: QuantizedEdits,
}

impl QuantizedComplexEdits {
    /// Quantize frequency edits kept in half-spectrum layout by the POCS
    /// fast path: the dense Hermitian vector is materialized here — once,
    /// at the cold coding boundary — so the stored stream (and therefore
    /// the archive bytes) are identical to quantizing the full vector.
    pub fn quantize_half(edits: &crate::fourier::HalfSpectrum) -> Self {
        Self::quantize(&edits.expand())
    }

    pub fn quantize(edits: &[Complex]) -> Self {
        let re: Vec<f64> = edits.iter().map(|c| c.re).collect();
        let im: Vec<f64> = edits.iter().map(|c| c.im).collect();
        Self {
            re: QuantizedEdits::quantize(&re),
            im: QuantizedEdits::quantize(&im),
        }
    }

    pub fn dequantize(&self) -> Vec<Complex> {
        let re = self.re.dequantize();
        let im = self.im.dequantize();
        re.into_iter()
            .zip(im)
            .map(|(r, i)| Complex::new(r, i))
            .collect()
    }

    /// Components with a nonzero edit in either plane.
    pub fn active(&self) -> usize {
        // idx lists are sorted: merge-count the union.
        let (a, b) = (&self.re.idx, &self.im.idx);
        let (mut i, mut j, mut count) = (0usize, 0usize, 0usize);
        while i < a.len() && j < b.len() {
            count += 1;
            match a[i].cmp(&b[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    i += 1;
                    j += 1;
                }
            }
        }
        count + (a.len() - i) + (b.len() - j)
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = self.re.to_bytes();
        out.extend_from_slice(&self.im.to_bytes());
        out
    }

    pub fn from_bytes(buf: &[u8], pos: &mut usize) -> Result<Self> {
        let re = QuantizedEdits::from_bytes(buf, pos)?;
        let im = QuantizedEdits::from_bytes(buf, pos)?;
        Ok(Self { re, im })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;

    fn sparse_edits(n: usize, density: f64, amp: f64, seed: u64) -> Vec<f64> {
        let mut rng = XorShift::new(seed);
        (0..n)
            .map(|_| {
                if rng.next_f64() < density {
                    rng.uniform(-amp, amp)
                } else {
                    0.0
                }
            })
            .collect()
    }

    #[test]
    fn quantize_error_within_half_step() {
        let edits = sparse_edits(1000, 0.05, 0.3, 1);
        let q = QuantizedEdits::quantize(&edits);
        let deq = q.dequantize();
        for (a, b) in edits.iter().zip(&deq) {
            assert!((a - b).abs() <= q.step / 2.0 + 1e-15);
        }
    }

    #[test]
    fn all_zero_stream_is_trivial() {
        let q = QuantizedEdits::quantize(&[0.0; 100]);
        assert_eq!(q.active(), 0);
        assert_eq!(q.step, 0.0);
        assert_eq!(q.dequantize(), vec![0.0; 100]);
        let bytes = q.to_bytes();
        let mut pos = 0;
        let q2 = QuantizedEdits::from_bytes(&bytes, &mut pos).unwrap();
        assert_eq!(q, q2);
    }

    #[test]
    fn serialization_roundtrip() {
        let edits = sparse_edits(4096, 0.02, 1.5, 2);
        let q = QuantizedEdits::quantize(&edits);
        let bytes = q.to_bytes();
        let mut pos = 0;
        let q2 = QuantizedEdits::from_bytes(&bytes, &mut pos).unwrap();
        assert_eq!(pos, bytes.len());
        assert_eq!(q, q2);
        assert_eq!(q.dequantize(), q2.dequantize());
    }

    #[test]
    fn complex_roundtrip_and_active_union() {
        let n = 512;
        let mut rng = XorShift::new(3);
        let edits: Vec<Complex> = (0..n)
            .map(|i| {
                let re = if i % 7 == 0 { rng.normal() } else { 0.0 };
                let im = if i % 5 == 0 { rng.normal() } else { 0.0 };
                Complex::new(re, im)
            })
            .collect();
        let q = QuantizedComplexEdits::quantize(&edits);
        let expect_active = edits.iter().filter(|c| c.re != 0.0 || c.im != 0.0).count();
        assert_eq!(q.active(), expect_active);
        let bytes = q.to_bytes();
        let mut pos = 0;
        let q2 = QuantizedComplexEdits::from_bytes(&bytes, &mut pos).unwrap();
        assert_eq!(q, q2);
    }

    #[test]
    fn sparse_streams_are_compact() {
        // 10 active edits in a 100k vector must cost ≪ dense storage.
        let mut edits = vec![0.0f64; 100_000];
        let mut rng = XorShift::new(4);
        for _ in 0..10 {
            edits[rng.below(100_000)] = rng.normal();
        }
        let bytes = QuantizedEdits::quantize(&edits).to_bytes();
        assert!(bytes.len() < 2500, "sparse stream {} B", bytes.len());
    }

    #[test]
    fn truncated_input_errors() {
        let edits = sparse_edits(256, 0.1, 1.0, 5);
        let bytes = QuantizedEdits::quantize(&edits).to_bytes();
        let mut pos = 0;
        assert!(QuantizedEdits::from_bytes(&bytes[..bytes.len() / 2], &mut pos).is_err());
    }
}

/// Frequency-edit stream for **pointwise** bounds (power-spectrum mode).
///
/// A single global quantization step is untenable when `Δ_k` spans many
/// decades: components with tiny bounds need steps far below the global
/// `max|edit|/2¹⁵` grid. This stream stores, per active component, a
/// power-of-two step exponent tied to its own bound
/// (`s_k = base_step·2^{e_k} ≤ Δ_k·gap`), plus unbounded zigzag-varint
/// grid indices for Re/Im. Everything is self-contained — the decoder
/// needs no knowledge of the bounds.
#[derive(Debug, Clone, PartialEq)]
pub struct PointwiseQuantizedEdits {
    pub n: usize,
    /// Smallest representable step (exponent 0).
    pub base_step: f64,
    /// Active component indices (ascending).
    pub idx: Vec<u32>,
    /// Per-component power-of-two step exponents.
    pub step_exp: Vec<u8>,
    /// Grid indices for Re/Im at the active components.
    pub q_re: Vec<i64>,
    pub q_im: Vec<i64>,
}

impl PointwiseQuantizedEdits {
    /// Half-spectrum-layout counterpart of
    /// [`PointwiseQuantizedEdits::quantize`] (see
    /// [`QuantizedComplexEdits::quantize_half`]).
    pub fn quantize_half(
        edits: &crate::fourier::HalfSpectrum,
        bound_at: impl Fn(usize) -> f64,
        gap: f64,
    ) -> Self {
        Self::quantize(&edits.expand(), bound_at, gap)
    }

    /// Quantize a dense complex edit vector against pointwise bounds:
    /// each active component gets the largest power-of-two step
    /// `≤ bound_at(k)·gap`, so dequantization error ≤ `Δ_k·gap/2`.
    pub fn quantize(
        edits: &[Complex],
        bound_at: impl Fn(usize) -> f64,
        gap: f64,
    ) -> Self {
        let n = edits.len();
        // base_step: half the smallest active bound·gap (exponent ≥ 0).
        let mut min_target = f64::INFINITY;
        for (k, e) in edits.iter().enumerate() {
            if e.re != 0.0 || e.im != 0.0 {
                min_target = min_target.min(bound_at(k) * gap);
            }
        }
        if !min_target.is_finite() {
            return Self {
                n,
                base_step: 0.0,
                idx: Vec::new(),
                step_exp: Vec::new(),
                q_re: Vec::new(),
                q_im: Vec::new(),
            };
        }
        let base_step = (min_target / 2.0).max(f64::MIN_POSITIVE);
        let mut idx = Vec::new();
        let mut step_exp = Vec::new();
        let mut q_re = Vec::new();
        let mut q_im = Vec::new();
        for (k, e) in edits.iter().enumerate() {
            if e.re == 0.0 && e.im == 0.0 {
                continue;
            }
            let target = bound_at(k) * gap;
            let exp = ((target / base_step).log2().floor().max(0.0) as u32).min(255);
            let s = base_step * (2.0f64).powi(exp as i32);
            let gr = (e.re / s).round() as i64;
            let gi = (e.im / s).round() as i64;
            if gr == 0 && gi == 0 {
                continue;
            }
            idx.push(k as u32);
            step_exp.push(exp as u8);
            q_re.push(gr);
            q_im.push(gi);
        }
        Self {
            n,
            base_step,
            idx,
            step_exp,
            q_re,
            q_im,
        }
    }

    /// Reconstruct the dense edit vector (fully self-contained).
    pub fn dequantize(&self) -> Vec<Complex> {
        let mut out = vec![Complex::ZERO; self.n];
        for (((&k, &e), &gr), &gi) in self
            .idx
            .iter()
            .zip(&self.step_exp)
            .zip(&self.q_re)
            .zip(&self.q_im)
        {
            let s = self.base_step * (2.0f64).powi(e as i32);
            out[k as usize] = Complex::new(gr as f64 * s, gi as f64 * s);
        }
        out
    }

    pub fn active(&self) -> usize {
        self.idx.len()
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        varint::write(&mut out, self.n as u64);
        out.extend_from_slice(&self.base_step.to_le_bytes());
        varint::write(&mut out, self.idx.len() as u64);
        if self.idx.is_empty() {
            return out;
        }
        let mut flags = vec![false; self.n];
        for &i in &self.idx {
            flags[i as usize] = true;
        }
        let enc_flags = lossless_compress(&pack_flags(&flags));
        varint::write(&mut out, enc_flags.len() as u64);
        out.extend_from_slice(&enc_flags);
        let enc_exp = lossless_compress(&self.step_exp);
        varint::write(&mut out, enc_exp.len() as u64);
        out.extend_from_slice(&enc_exp);
        let mut vals = Vec::new();
        for &g in self.q_re.iter().chain(&self.q_im) {
            varint::write(&mut vals, varint::zigzag(g));
        }
        let enc_vals = lossless_compress(&vals);
        varint::write(&mut out, enc_vals.len() as u64);
        out.extend_from_slice(&enc_vals);
        out
    }

    pub fn from_bytes(buf: &[u8], pos: &mut usize) -> Result<Self> {
        let n = varint::read(buf, pos)? as usize;
        let base_step = fixed::read_f64_le(buf, pos, "pointwise edit base step")?;
        let count = varint::read(buf, pos)? as usize;
        if count == 0 {
            return Ok(Self {
                n,
                base_step,
                idx: Vec::new(),
                step_exp: Vec::new(),
                q_re: Vec::new(),
                q_im: Vec::new(),
            });
        }
        let flen = varint::read(buf, pos)? as usize;
        if *pos + flen > buf.len() {
            bail!("truncated pointwise flags");
        }
        let flags = unpack_flags(&lossless_decompress(&buf[*pos..*pos + flen])?, n);
        *pos += flen;
        let idx: Vec<u32> = flags
            .iter()
            .enumerate()
            .filter(|(_, &f)| f)
            .map(|(i, _)| i as u32)
            .collect();
        if idx.len() != count {
            bail!("pointwise flag count mismatch");
        }
        let elen = varint::read(buf, pos)? as usize;
        if *pos + elen > buf.len() {
            bail!("truncated step exponents");
        }
        let step_exp = lossless_decompress(&buf[*pos..*pos + elen])?;
        *pos += elen;
        if step_exp.len() != count {
            bail!("step exponent count mismatch");
        }
        let vlen = varint::read(buf, pos)? as usize;
        if *pos + vlen > buf.len() {
            bail!("truncated pointwise values");
        }
        let vals = lossless_decompress(&buf[*pos..*pos + vlen])?;
        *pos += vlen;
        let mut vpos = 0usize;
        let mut q_re = Vec::with_capacity(count);
        for _ in 0..count {
            q_re.push(varint::unzigzag(varint::read(&vals, &mut vpos)?));
        }
        let mut q_im = Vec::with_capacity(count);
        for _ in 0..count {
            q_im.push(varint::unzigzag(varint::read(&vals, &mut vpos)?));
        }
        Ok(Self {
            n,
            base_step,
            idx,
            step_exp,
            q_re,
            q_im,
        })
    }
}

#[cfg(test)]
mod pointwise_tests {
    use super::*;
    use crate::util::XorShift;

    fn setup(n: usize, seed: u64) -> (Vec<Complex>, Vec<f64>) {
        let mut rng = XorShift::new(seed);
        let bounds: Vec<f64> = (0..n).map(|_| 10f64.powf(rng.uniform(-6.0, 0.0))).collect();
        let edits: Vec<Complex> = bounds
            .iter()
            .map(|&b| {
                if rng.next_f64() < 0.5 {
                    // edits can be far larger than the local bound
                    Complex::new(rng.normal() * b * 100.0, rng.normal() * b * 100.0)
                } else {
                    Complex::ZERO
                }
            })
            .collect();
        (edits, bounds)
    }

    #[test]
    fn error_within_local_bound_gap() {
        let (edits, bounds) = setup(2048, 1);
        let gap = 2.0f64.powi(-7);
        let q = PointwiseQuantizedEdits::quantize(&edits, |k| bounds[k], gap);
        let deq = q.dequantize();
        for (k, (a, b)) in edits.iter().zip(&deq).enumerate() {
            let tol = bounds[k] * gap / 2.0 + 1e-300;
            assert!((a.re - b.re).abs() <= tol, "k={k}");
            assert!((a.im - b.im).abs() <= tol, "k={k}");
        }
    }

    #[test]
    fn serialization_roundtrip() {
        let (edits, bounds) = setup(4096, 2);
        let q = PointwiseQuantizedEdits::quantize(&edits, |k| bounds[k], 1e-2);
        let bytes = q.to_bytes();
        let mut pos = 0;
        let q2 = PointwiseQuantizedEdits::from_bytes(&bytes, &mut pos).unwrap();
        assert_eq!(pos, bytes.len());
        assert_eq!(q, q2);
    }

    #[test]
    fn dense_edits_cost_few_bytes_per_component() {
        let (edits, bounds) = setup(8192, 3);
        let q = PointwiseQuantizedEdits::quantize(&edits, |k| bounds[k], 1e-2);
        let bytes = q.to_bytes();
        let per = bytes.len() as f64 / q.active() as f64;
        assert!(per < 8.0, "bytes/active {per:.1}");
    }

    #[test]
    fn empty_stream_roundtrip() {
        let q = PointwiseQuantizedEdits::quantize(&[], |_| 1.0, 1e-2);
        let bytes = q.to_bytes();
        let mut pos = 0;
        let q2 = PointwiseQuantizedEdits::from_bytes(&bytes, &mut pos).unwrap();
        assert_eq!(q, q2);
    }
}
