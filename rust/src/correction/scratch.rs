//! Reusable transform state for the encode hot path.
//!
//! Before this module, every verify/retry step around the POCS loop —
//! [`super::check_dual_bounds`], [`super::resolve_bounds`], the
//! quantization ladder's re-checks in [`super::correct_reconstruction`] —
//! rebuilt an [`NdRealFft`] plan and allocated a fresh workspace plus
//! spectrum buffers per call. One chunk encode pays that cost several
//! times (bound resolution, one projection per shrink attempt, one dual
//! verify per attempt, final archive verification), once per chunk, per
//! store worker.
//!
//! A [`CorrectionScratch`] owns all of that state once: shared plan
//! *handles* from the process-wide plan cache ([`ndrplan_for`], keyed by
//! chunk shape, so mixed-shape grids — edge chunks — re-warm only on first
//! contact with each shape), one grow-only [`NdFftWorkspace`], and
//! grow-only half-spectrum / real staging buffers. Threading one scratch
//! through a chunk's whole retry ladder (and reusing it across chunks on a
//! store worker) makes the steady-state encode path allocation-free in the
//! scratch-managed state: after warm-up on a shape, a chunk encode
//! performs **zero** scratch allocations, observable through
//! [`CorrectionScratch::allocation_events`] (the gauge the encode bench
//! emits and CI asserts stays zero — buffers that *escape* into results,
//! like edit vectors and archive payloads, are inherent outputs and are
//! not scratch).
//!
//! Scratch contents never influence results: every buffer is fully
//! overwritten before it is read, so scratch-reusing encodes are
//! bit-identical to fresh-state encodes (property-tested across shapes and
//! bound modes in `rust/tests/properties.rs`).

use std::collections::HashMap;
use std::sync::Arc;

use crate::fourier::{ndrplan_for, Complex, NdFftWorkspace, NdRealFft};

/// Reusable per-worker (or per-call-site) scratch for the correction
/// encode path. See the module docs; obtain one with
/// [`CorrectionScratch::new`] and hand it to the `*_with_scratch` entry
/// points in [`crate::correction`] and [`crate::codec`].
pub struct CorrectionScratch {
    /// Shared plan handles, one per chunk shape seen by this scratch.
    plans: HashMap<Vec<usize>, Arc<NdRealFft>>,
    /// Line-engine workspace (gather blocks + 1-D scratch), grow-only.
    pub(crate) ws: NdFftWorkspace,
    /// Primary half-spectrum buffer (POCS δ, verifier spectra), grow-only.
    pub(crate) spec: Vec<Complex>,
    /// Secondary half-spectrum buffer (Hermitian fold targets), grow-only.
    pub(crate) spec2: Vec<Complex>,
    /// Real staging buffer (corrected-ε candidates), grow-only.
    pub(crate) real: Vec<f64>,
    /// Own buffer-growth / plan-miss events (workspace events counted
    /// separately by [`NdFftWorkspace::grow_events`]).
    grows: u64,
}

impl CorrectionScratch {
    pub fn new() -> Self {
        Self {
            plans: HashMap::new(),
            ws: NdFftWorkspace::new(),
            spec: Vec::new(),
            spec2: Vec::new(),
            real: Vec::new(),
            grows: 0,
        }
    }

    /// Shared [`NdRealFft`] plan handle for `shape` (first contact with a
    /// shape counts one allocation event; later calls are a map hit).
    pub(crate) fn plan(&mut self, shape: &[usize]) -> Arc<NdRealFft> {
        if let Some(plan) = self.plans.get(shape) {
            return plan.clone();
        }
        self.grows += 1;
        let plan = ndrplan_for(shape);
        self.plans.insert(shape.to_vec(), plan.clone());
        plan
    }

    /// Grow (never shrink) the primary half-spectrum buffer to `len`.
    pub(crate) fn ensure_spec(&mut self, len: usize) {
        if self.spec.len() < len {
            self.spec.resize(len, Complex::ZERO);
            self.grows += 1;
        }
    }

    /// Grow (never shrink) the secondary half-spectrum buffer to `len`.
    pub(crate) fn ensure_spec2(&mut self, len: usize) {
        if self.spec2.len() < len {
            self.spec2.resize(len, Complex::ZERO);
            self.grows += 1;
        }
    }

    /// Grow (never shrink) the real staging buffer to `len`.
    pub(crate) fn ensure_real(&mut self, len: usize) {
        if self.real.len() < len {
            self.real.resize(len, 0.0);
            self.grows += 1;
        }
    }

    /// Allocation/growth events recorded so far: plan-cache first
    /// contacts, scratch-buffer growth, and workspace lane/buffer growth.
    /// The steady-state encode gauge: after one chunk of a given shape has
    /// warmed the scratch, further chunks of that shape add **zero**.
    pub fn allocation_events(&self) -> u64 {
        self.grows + self.ws.grow_events()
    }
}

impl Default for CorrectionScratch {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_handles_are_shared_and_keyed() {
        let mut s = CorrectionScratch::new();
        let a = s.plan(&[4, 6]);
        let e1 = s.allocation_events();
        let b = s.plan(&[4, 6]);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(s.allocation_events(), e1, "repeat plan fetch allocated");
        let _ = s.plan(&[6, 4]);
        assert!(s.allocation_events() > e1, "new shape must count an event");
    }

    #[test]
    fn buffers_grow_monotonically_and_count_events() {
        let mut s = CorrectionScratch::new();
        s.ensure_spec(16);
        s.ensure_real(32);
        let warm = s.allocation_events();
        assert_eq!(warm, 2);
        // Smaller or equal requests are free.
        s.ensure_spec(8);
        s.ensure_spec(16);
        s.ensure_real(32);
        assert_eq!(s.allocation_events(), warm);
        assert_eq!(s.spec.len(), 16);
        assert_eq!(s.real.len(), 32);
        // Growth counts again.
        s.ensure_spec2(4);
        s.ensure_spec(64);
        assert_eq!(s.allocation_events(), warm + 2);
    }
}
