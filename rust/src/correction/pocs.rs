//! Alternating projection onto the s-cube and f-cube (POCS, paper §IV-B).
//!
//! Starting from the spatial error vector `ε = x̂ − x` of the base
//! compressor (inside the s-cube by construction), the loop alternates:
//!
//! 1. `δ = FFT(ε)`; if every component satisfies `|Re δ_k| ≤ Δ_k` and
//!    `|Im δ_k| ≤ Δ_k`, stop — `ε` is in the intersection;
//! 2. project onto the **f-cube** by clipping `δ` componentwise, recording
//!    the displacement as *frequency edits* (along the frequency basis);
//! 3. `ε = IFFT(δ)`; project onto the **s-cube** by clipping `ε` to
//!    `±E_n`, recording the displacement as *spatial edits*.
//!
//! Because `ε` is real and the per-component bounds are Hermitian-symmetric
//! (`Δ_{−k} = Δ_k` — always true for the bounds this crate derives, since
//! pointwise bounds come from `|X_k|` of a real field), the spectrum stays
//! Hermitian through every projection. [`alternating_projection`] therefore
//! runs the whole loop on the **half spectrum** via
//! [`crate::fourier::NdRealFft`]: half the transform arithmetic, half the clip work, half
//! the memory traffic, with frequency edits accumulated in
//! [`HalfSpectrum`] layout and expanded only at the (cold) quantization
//! boundary. Transforms reuse one [`crate::fourier::NdFftWorkspace`] across iterations, so
//! the steady state allocates nothing, and `threads` fans the N-D line
//! transforms across OS threads (bit-identical output for any count).
//!
//! [`alternating_projection_reference`] keeps the original full-complex
//! loop as the correctness oracle; property tests assert the two agree to
//! 1e-10. If pointwise frequency bounds are *not* Hermitian-symmetric
//! (impossible through [`crate::correction::resolve_bounds`], but reachable
//! through the public `Bounds` API), the fast path detects it and falls
//! back to the reference loop, so the projection is correct for every
//! input.

use crate::fourier::{
    fftn_inplace, for_each_full_bin, for_each_row_with_mirror, ifftn_inplace, Complex,
    HalfSpectrum,
};

use super::scratch::CorrectionScratch;

/// Per-axis bounds: one global scalar or a full pointwise vector.
#[derive(Debug, Clone)]
pub enum Bounds {
    Global(f64),
    Pointwise(Vec<f64>),
}

impl Bounds {
    #[inline]
    pub fn at(&self, i: usize) -> f64 {
        match self {
            Bounds::Global(b) => *b,
            Bounds::Pointwise(v) => v[i],
        }
    }

    /// Multiply every bound by `f` (used for the quantization shrink).
    pub fn scaled(&self, f: f64) -> Bounds {
        match self {
            Bounds::Global(b) => Bounds::Global(b * f),
            Bounds::Pointwise(v) => Bounds::Pointwise(v.iter().map(|b| b * f).collect()),
        }
    }
}

/// Outcome of the alternating projection.
#[derive(Debug, Clone)]
pub struct PocsResult {
    /// Corrected spatial error vector (real).
    pub corrected_eps: Vec<f64>,
    /// Cumulative spatial edits (length N; sparse in practice).
    pub spat_edits: Vec<f64>,
    /// Cumulative frequency edits in half-spectrum layout (sparse in
    /// practice; [`HalfSpectrum::expand`] recovers the full Hermitian
    /// vector on demand).
    pub freq_edits: HalfSpectrum,
    /// Number of loop iterations executed (paper Table III).
    pub iterations: usize,
    /// Whether the loop hit the f-cube constraint before `max_iters`.
    pub converged: bool,
    /// Count of nonzero spatial edits.
    pub active_spat: usize,
    /// Count of full-spectrum frequency components with a nonzero edit.
    pub active_freq: usize,
}

/// Configuration of one projection run.
#[derive(Debug, Clone)]
pub struct PocsParams {
    /// Spatial bounds `E_n` (s-cube half-widths).
    pub spatial: Bounds,
    /// Frequency bounds `Δ_k` applied to Re and Im independently
    /// (f-cube half-widths).
    pub frequency: Bounds,
    /// Iteration cap; the paper observes 1–100 iterations in practice.
    pub max_iters: usize,
    /// OS threads for the N-D line transforms inside the loop (1 =
    /// single-threaded, 0 is clamped to 1; the result is bit-identical
    /// for every value).
    pub threads: usize,
}

/// Relative FFT-roundoff tolerance for the convergence check: a bound
/// exceedance is only *significant* (keeps the loop running) beyond this
/// margin — without it the loop can chase 1-ulp exceedances forever.
const VIOLATION_SLACK: f64 = 1.0 + 1e-10;

/// Roundoff tolerance shared by every dual-bound *verifier* (the
/// projector itself clips hard): a normalized ratio ≤ this counts as
/// in-bound. One constant so the retry ladder's accept/reject
/// ([`super::correct_reconstruction`]) can never drift from the archive
/// verifier ([`check_dual_bounds`]).
pub(crate) const VERIFIER_TOL: f64 = 1.0 + 1e-9;

/// `max_i |ε_i| / E_i` (≤ 1 is in-bound; a zero bound is satisfied only
/// by an exactly-zero component).
pub(crate) fn max_spatial_ratio(eps: &[f64], spatial: &Bounds) -> f64 {
    let mut max_s = 0.0f64;
    for (i, &e) in eps.iter().enumerate() {
        let b = spatial.at(i);
        let r = if b > 0.0 { e.abs() / b } else if e == 0.0 { 0.0 } else { f64::INFINITY };
        max_s = max_s.max(r);
    }
    max_s
}

/// `max_k ‖δ_k‖∞ / Δ_k` over the full bin lattice, read from the half
/// spectrum (`ε` is real and `‖conj(z)‖∞ = ‖z‖∞`, so this is exact even
/// for asymmetric pointwise bounds).
pub(crate) fn max_frequency_ratio_half(
    spec: &[Complex],
    shape: &[usize],
    frequency: &Bounds,
) -> f64 {
    let mut max_f = 0.0f64;
    for_each_full_bin(shape, |full, half, _conj| {
        let b = frequency.at(full);
        let linf = spec[half].linf();
        let r = if b > 0.0 { linf / b } else if linf == 0.0 { 0.0 } else { f64::INFINITY };
        max_f = max_f.max(r);
    });
    max_f
}

/// Run the alternating projection on the spatial error vector `eps0` of a
/// row-major field with `shape`.
///
/// This is the half-spectrum fast path (see the module docs); it produces
/// the same corrections as [`alternating_projection_reference`] up to FFT
/// rounding (≤ 1e-10 relative, asserted by the property tests). Plan and
/// transform scratch are built per call; the encode hot path reuses them
/// across retries and chunks through
/// [`alternating_projection_with_scratch`].
pub fn alternating_projection(eps0: &[f64], shape: &[usize], params: &PocsParams) -> PocsResult {
    let mut scratch = CorrectionScratch::new();
    alternating_projection_with_scratch(eps0, shape, params, &mut scratch)
}

/// `correction.pocs.rfft_fallbacks`: projections that left the
/// half-spectrum fast path for the full-complex reference loop because
/// the pointwise frequency bounds were not Hermitian-symmetric.
fn rfft_fallbacks() -> &'static crate::telemetry::Counter {
    static COUNTER: std::sync::OnceLock<crate::telemetry::Counter> = std::sync::OnceLock::new();
    COUNTER.get_or_init(|| crate::telemetry::counter("correction.pocs.rfft_fallbacks"))
}

/// [`alternating_projection`] with caller-owned transform state: the plan
/// handle, line-engine workspace, and δ half-spectrum buffer come from
/// `scratch` (grown on first contact with `shape`, reused afterwards), so
/// a warmed scratch makes every further projection of the same shape
/// allocation-free in the scratch-managed state. Results are bit-identical
/// to the fresh-state entry point: every scratch buffer is fully
/// overwritten before it is read. The edit/result vectors themselves are
/// freshly allocated — they escape into the returned [`PocsResult`].
pub fn alternating_projection_with_scratch(
    eps0: &[f64],
    shape: &[usize],
    params: &PocsParams,
    scratch: &mut CorrectionScratch,
) -> PocsResult {
    let n = eps0.len();
    debug_assert_eq!(n, shape.iter().product::<usize>());
    // The half-spectrum projection is only equivalent when clipping a bin
    // also clips its Hermitian mate identically. Asymmetric pointwise
    // bounds (never produced by this crate's bound resolution) go through
    // the full-spectrum reference loop instead.
    if let Bounds::Pointwise(v) = &params.frequency {
        if !bounds_hermitian_symmetric(v, shape) {
            rfft_fallbacks().incr();
            return alternating_projection_reference(eps0, shape, params);
        }
    }
    let threads = params.threads.max(1);
    let plan = scratch.plan(shape);
    let last = shape[shape.len() - 1];
    let h = last / 2 + 1;
    let h_total = plan.half_len();
    let rows = h_total / h;
    scratch.ensure_spec(h_total);
    let CorrectionScratch { spec, ws, .. } = scratch;
    let mut spec = &mut spec[..h_total];

    let mut eps: Vec<f64> = eps0.to_vec();
    let mut spat_edits = vec![0.0f64; n];
    let mut freq_half = vec![Complex::ZERO; h_total];
    let mut iterations = 0usize;
    let mut converged = false;

    while iterations < params.max_iters {
        iterations += 1;
        // δ = FFT(ε), half spectrum only.
        plan.forward(&eps, spec, threads, ws);

        // Convergence check + f-cube projection fused in one pass over the
        // half bins. Clipping a stored bin implicitly clips its Hermitian
        // mate (conjugate value, equal bound), exactly as the reference
        // clips both. Sub-tolerance exceedances are still clipped (and
        // recorded) before terminating. The Global/Pointwise dispatch is
        // hoisted out of the hot loop.
        let mut violated = false;
        let mut clip_f = |hk: usize, d: f64, spec: &mut [Complex]| {
            let v = spec[hk];
            let re = v.re.clamp(-d, d);
            let im = v.im.clamp(-d, d);
            if re != v.re || im != v.im {
                if v.linf() > d * VIOLATION_SLACK {
                    violated = true;
                }
                let clipped = Complex::new(re, im);
                freq_half[hk] += clipped - v;
                spec[hk] = clipped;
            }
        };
        match &params.frequency {
            Bounds::Global(d) => {
                let d = *d;
                for hk in 0..h_total {
                    clip_f(hk, d, &mut spec);
                }
            }
            Bounds::Pointwise(v) => {
                // Bound index = full-spectrum linear index of the stored
                // bin: row r of the half buffer holds full bins
                // r·last + 0..h.
                for r in 0..rows {
                    for k in 0..h {
                        clip_f(r * h + k, v[r * last + k], &mut spec);
                    }
                }
            }
        }

        // Back to the spatial basis (ε stays real by construction).
        plan.inverse(&mut spec, &mut eps, threads, ws);
        if !violated {
            // Already inside the f-cube: stop.
            converged = true;
            break;
        }

        // s-cube projection.
        let mut clip_s = |i: usize, e: f64, eps: &mut [f64]| {
            let v = eps[i];
            let clipped = v.clamp(-e, e);
            if clipped != v {
                spat_edits[i] += clipped - v;
                eps[i] = clipped;
            }
        };
        match &params.spatial {
            Bounds::Global(e) => {
                let e = *e;
                for i in 0..n {
                    clip_s(i, e, &mut eps);
                }
            }
            Bounds::Pointwise(v) => {
                for i in 0..n {
                    clip_s(i, v[i], &mut eps);
                }
            }
        }
    }

    let active_spat = spat_edits.iter().filter(|&&e| e != 0.0).count();
    let freq_edits = HalfSpectrum::from_parts(shape, freq_half);
    let active_freq = freq_edits.active_full();
    PocsResult {
        corrected_eps: eps,
        spat_edits,
        freq_edits,
        iterations,
        converged,
        active_spat,
        active_freq,
    }
}

/// `Δ_{−k} == Δ_k` for every component of the full lattice (the condition
/// under which clipping the half spectrum is exactly the reference
/// projection — including the `k_last = 0` / Nyquist planes, whose
/// conjugate mates are stored bins themselves). Deliberately walks the
/// **full** lattice — [`for_each_row_with_mirror`] with the complete
/// `shape`, not just the leading dims — so asymmetry anywhere is caught.
fn bounds_hermitian_symmetric(v: &[f64], shape: &[usize]) -> bool {
    debug_assert_eq!(v.len(), shape.iter().product::<usize>());
    let mut symmetric = true;
    for_each_row_with_mirror(shape, |i, mirror| {
        if v[mirror] != v[i] {
            symmetric = false;
        }
    });
    symmetric
}

/// The original full-complex-spectrum projection loop, kept as the
/// correctness oracle for [`alternating_projection`] (equivalence-tested to
/// 1e-10) and as the fallback for non-Hermitian pointwise bounds.
pub fn alternating_projection_reference(
    eps0: &[f64],
    shape: &[usize],
    params: &PocsParams,
) -> PocsResult {
    let n = eps0.len();
    debug_assert_eq!(n, shape.iter().product::<usize>());
    let mut eps: Vec<Complex> = eps0.iter().map(|&e| Complex::new(e, 0.0)).collect();
    let mut spat_edits = vec![0.0f64; n];
    let mut freq_edits = vec![Complex::ZERO; n];
    let mut iterations = 0usize;
    let mut converged = false;

    while iterations < params.max_iters {
        iterations += 1;
        // δ = FFT(ε)
        fftn_inplace(&mut eps, shape);

        let mut violated = false;
        let mut clip_f = |k: usize, d: f64, eps: &mut [Complex]| {
            let v = eps[k];
            let re = v.re.clamp(-d, d);
            let im = v.im.clamp(-d, d);
            if re != v.re || im != v.im {
                if v.linf() > d * VIOLATION_SLACK {
                    violated = true;
                }
                let clipped = Complex::new(re, im);
                freq_edits[k] += clipped - v;
                eps[k] = clipped;
            }
        };
        match &params.frequency {
            Bounds::Global(d) => {
                let d = *d;
                for k in 0..n {
                    clip_f(k, d, &mut eps);
                }
            }
            Bounds::Pointwise(v) => {
                for k in 0..n {
                    clip_f(k, v[k], &mut eps);
                }
            }
        }
        if !violated {
            // Already inside the f-cube: undo the transform and stop.
            ifftn_inplace(&mut eps, shape);
            converged = true;
            break;
        }

        // Back to the spatial basis.
        ifftn_inplace(&mut eps, shape);

        // s-cube projection (drop rounding-level imaginary residue).
        let mut clip_s = |i: usize, e: f64, eps: &mut [Complex]| {
            let v = eps[i].re;
            let clipped = v.clamp(-e, e);
            if clipped != v {
                spat_edits[i] += clipped - v;
            }
            eps[i] = Complex::new(clipped, 0.0);
        };
        match &params.spatial {
            Bounds::Global(e) => {
                let e = *e;
                for i in 0..n {
                    clip_s(i, e, &mut eps);
                }
            }
            Bounds::Pointwise(v) => {
                for i in 0..n {
                    clip_s(i, v[i], &mut eps);
                }
            }
        }
    }

    let corrected_eps: Vec<f64> = eps.iter().map(|c| c.re).collect();
    let active_spat = spat_edits.iter().filter(|&&e| e != 0.0).count();
    let active_freq = freq_edits
        .iter()
        .filter(|c| c.re != 0.0 || c.im != 0.0)
        .count();
    PocsResult {
        corrected_eps,
        spat_edits,
        // Half-spectrum storage via the Hermitian *projection*: with
        // symmetric bounds the edits are already Hermitian and the fold is
        // an identity (up to averaging rounding noise across mates); with
        // asymmetric pointwise bounds (the fallback case) the edits are
        // not, but only their Hermitian part ever reaches the real ε —
        // `irfftn(fold(F)) == Re(ifftn(F))` exactly — so the
        // edits-reconstruct-the-correction invariant holds either way.
        freq_edits: HalfSpectrum::fold_full(&freq_edits, shape),
        iterations,
        converged,
        active_spat,
        active_freq,
    }
}

/// Check the dual-domain constraints for an error vector (used by tests and
/// the archive verifier). Returns `(spatial_ok, frequency_ok, max_spat,
/// max_freq_linf)` where the maxima are normalized by their bound (≤ 1 is
/// in-bound).
///
/// The frequency check walks the full bin lattice but transforms only the
/// half spectrum (`ε` is real, and `‖conj(z)‖∞ = ‖z‖∞`), so it is exact for
/// arbitrary — even asymmetric — pointwise bounds at half the FFT cost.
pub fn check_dual_bounds(
    eps: &[f64],
    shape: &[usize],
    spatial: &Bounds,
    frequency: &Bounds,
) -> (bool, bool, f64, f64) {
    let mut scratch = CorrectionScratch::new();
    check_dual_bounds_with_scratch(eps, shape, spatial, frequency, 1, &mut scratch)
}

/// [`check_dual_bounds`] with caller-owned transform state (and an
/// explicit `threads` count for the verification transform — the output is
/// bit-identical for every value, see [`crate::fourier::NdRealFft`]). The
/// encode retry ladder calls this once per quantization attempt; a warmed
/// scratch makes each call allocation-free.
pub fn check_dual_bounds_with_scratch(
    eps: &[f64],
    shape: &[usize],
    spatial: &Bounds,
    frequency: &Bounds,
    threads: usize,
    scratch: &mut CorrectionScratch,
) -> (bool, bool, f64, f64) {
    let max_s = max_spatial_ratio(eps, spatial);
    let plan = scratch.plan(shape);
    scratch.ensure_spec(plan.half_len());
    let CorrectionScratch { spec, ws, .. } = scratch;
    let spec = &mut spec[..plan.half_len()];
    plan.forward(eps, spec, threads.max(1), ws);
    let max_f = max_frequency_ratio_half(spec, shape, frequency);
    (max_s <= VERIFIER_TOL, max_f <= VERIFIER_TOL, max_s, max_f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fourier::ifftn_inplace;
    use crate::util::XorShift;

    fn random_eps(n: usize, e: f64, seed: u64) -> Vec<f64> {
        let mut rng = XorShift::new(seed);
        (0..n).map(|_| rng.uniform(-e, e)).collect()
    }

    #[test]
    fn already_feasible_terminates_in_one_iteration() {
        // Huge Δ ⇒ f-cube contains everything the s-cube can produce.
        let n = 64;
        let eps = random_eps(n, 0.1, 1);
        let params = PocsParams {
            spatial: Bounds::Global(0.1),
            frequency: Bounds::Global(1e6),
            max_iters: 100,
            threads: 1,
        };
        let r = alternating_projection(&eps, &[n], &params);
        assert!(r.converged);
        assert_eq!(r.iterations, 1);
        assert_eq!(r.active_spat, 0);
        assert_eq!(r.active_freq, 0);
        for (a, b) in r.corrected_eps.iter().zip(&eps) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn dual_bounds_hold_after_projection() {
        for seed in 0..5u64 {
            let n = 128;
            let e = 0.05;
            let eps = random_eps(n, e, seed);
            // Tight frequency bound forces actual work.
            let delta = 0.2;
            let params = PocsParams {
                spatial: Bounds::Global(e),
                frequency: Bounds::Global(delta),
                max_iters: 500,
                threads: 1,
            };
            let r = alternating_projection(&eps, &[n], &params);
            assert!(r.converged, "seed {seed} did not converge");
            let (s_ok, f_ok, ms, mf) = check_dual_bounds(
                &r.corrected_eps,
                &[n],
                &params.spatial,
                &params.frequency,
            );
            assert!(s_ok && f_ok, "seed {seed}: max_s {ms} max_f {mf}");
        }
    }

    #[test]
    fn edits_reconstruct_the_correction() {
        // corrected ε == ε₀ + spat_edits + IFFT(freq_edits): the two edit
        // streams fully describe the correction (paper §IV-B "applying
        // edits").
        let n = 64;
        let eps = random_eps(n, 0.1, 7);
        let params = PocsParams {
            spatial: Bounds::Global(0.1),
            frequency: Bounds::Global(0.3),
            max_iters: 500,
            threads: 1,
        };
        let r = alternating_projection(&eps, &[n], &params);
        let mut freq_part = r.freq_edits.expand();
        ifftn_inplace(&mut freq_part, &[n]);
        for i in 0..n {
            let rebuilt = eps[i] + r.spat_edits[i] + freq_part[i].re;
            assert!(
                (rebuilt - r.corrected_eps[i]).abs() < 1e-10,
                "i={i}: {rebuilt} vs {}",
                r.corrected_eps[i]
            );
        }
    }

    #[test]
    fn tiny_delta_clips_everything_first_pass() {
        // Paper Table III: very small Δ ⇒ f-cube inside s-cube ⇒ massive
        // frequency clipping but zero *spatial* edits, 1–2 iterations.
        let n = 256;
        let eps = random_eps(n, 0.1, 3);
        let params = PocsParams {
            spatial: Bounds::Global(0.1),
            frequency: Bounds::Global(1e-6),
            max_iters: 50,
            threads: 1,
        };
        let r = alternating_projection(&eps, &[n], &params);
        assert!(r.converged);
        assert!(r.active_freq > n / 2, "freq edits {}", r.active_freq);
        assert!(r.iterations <= 3, "iterations {}", r.iterations);
    }

    #[test]
    fn pointwise_bounds_respected() {
        let n = 32;
        let eps = random_eps(n, 0.2, 9);
        let spat: Vec<f64> = (0..n).map(|i| 0.05 + 0.01 * (i % 5) as f64).collect();
        // Hermitian-symmetric frequency bounds (as resolve_bounds builds).
        let freq: Vec<f64> = (0..n)
            .map(|k| {
                let m = k.min(n - k);
                if m % 2 == 0 { 0.5 } else { 0.1 }
            })
            .collect();
        let params = PocsParams {
            spatial: Bounds::Pointwise(spat.clone()),
            frequency: Bounds::Pointwise(freq.clone()),
            max_iters: 1000,
            threads: 1,
        };
        // Start inside the s-cube: clip the input first.
        let eps: Vec<f64> = eps
            .iter()
            .enumerate()
            .map(|(i, &e)| e.clamp(-spat[i], spat[i]))
            .collect();
        let r = alternating_projection(&eps, &[n], &params);
        assert!(r.converged);
        let (s_ok, f_ok, ..) = check_dual_bounds(
            &r.corrected_eps,
            &[n],
            &params.spatial,
            &params.frequency,
        );
        assert!(s_ok && f_ok);
    }

    #[test]
    fn asymmetric_pointwise_bounds_fall_back_to_reference() {
        // Bounds with Δ_{−k} ≠ Δ_k cannot use the half-spectrum path; the
        // dispatcher must still produce a projection inside both cubes.
        let n = 16;
        let eps = random_eps(n, 0.1, 21);
        let freq: Vec<f64> = (0..n).map(|k| 0.1 + 0.02 * k as f64).collect();
        let params = PocsParams {
            spatial: Bounds::Global(0.1),
            frequency: Bounds::Pointwise(freq),
            max_iters: 1000,
            threads: 1,
        };
        let r = alternating_projection(&eps, &[n], &params);
        assert!(r.converged);
        let (s_ok, f_ok, ms, mf) =
            check_dual_bounds(&r.corrected_eps, &[n], &params.spatial, &params.frequency);
        assert!(s_ok && f_ok, "max_s {ms} max_f {mf}");
        // The reference's edits are non-Hermitian under asymmetric bounds,
        // but the stored Hermitian projection must still reconstruct the
        // correction: ε' == ε₀ + spat + Re(IFFT(freq)).
        let mut freq = r.freq_edits.expand();
        ifftn_inplace(&mut freq, &[n]);
        for i in 0..n {
            let rebuilt = eps[i] + r.spat_edits[i] + freq[i].re;
            assert!(
                (rebuilt - r.corrected_eps[i]).abs() < 1e-10,
                "i={i}: {rebuilt} vs {}",
                r.corrected_eps[i]
            );
        }
    }

    #[test]
    fn works_in_2d_and_3d() {
        for shape in [vec![16usize, 16], vec![8, 8, 8]] {
            let n: usize = shape.iter().product();
            let eps = random_eps(n, 0.1, 11);
            let params = PocsParams {
                spatial: Bounds::Global(0.1),
                frequency: Bounds::Global(0.4),
                max_iters: 500,
                threads: 1,
            };
            let r = alternating_projection(&eps, &shape, &params);
            assert!(r.converged, "shape {shape:?}");
            let (s_ok, f_ok, ..) =
                check_dual_bounds(&r.corrected_eps, &shape, &params.spatial, &params.frequency);
            assert!(s_ok && f_ok, "shape {shape:?}");
        }
    }

    #[test]
    fn hermitian_symmetry_keeps_eps_real() {
        // After many iterations the imaginary residue must stay at rounding
        // level — checked implicitly by corrected_eps being the full state.
        let n = 100; // non-pow2 exercises Bluestein too
        let eps = random_eps(n, 0.1, 13);
        let params = PocsParams {
            spatial: Bounds::Global(0.1),
            frequency: Bounds::Global(0.25),
            max_iters: 400,
            threads: 1,
        };
        let r = alternating_projection(&eps, &[n], &params);
        assert!(r.converged);
        // Feed the corrected ε back: it must already be feasible (fixpoint).
        let r2 = alternating_projection(&r.corrected_eps, &[n], &params);
        assert_eq!(r2.iterations, 1);
        assert!(r2.converged);
    }

    /// Fast path vs reference oracle: corrections agree to 1e-10 and the
    /// expanded frequency edits match, across dimensionalities and FFT
    /// kernels (pow2, odd/Bluestein, mixed).
    #[test]
    fn fast_path_matches_reference() {
        for (shape, seed) in [
            (vec![64usize], 1u64),
            (vec![100], 2),
            (vec![45], 3),
            (vec![16, 16], 4),
            (vec![12, 10], 5),
            (vec![8, 8, 8], 6),
            (vec![6, 5, 9], 7),
        ] {
            let n: usize = shape.iter().product();
            let e = 0.1;
            let eps = random_eps(n, e, seed);
            let d = 0.25 * e * (n as f64).sqrt();
            let params = PocsParams {
                spatial: Bounds::Global(e),
                frequency: Bounds::Global(d),
                max_iters: 1000,
                threads: 1,
            };
            let fast = alternating_projection(&eps, &shape, &params);
            let reference = alternating_projection_reference(&eps, &shape, &params);
            // The engines differ at FFT-rounding level, so the final
            // convergence check can fire one iteration apart when an
            // overshoot sits exactly on the tolerance; the *corrections*
            // still agree to 1e-10 below.
            let di = fast.iterations.abs_diff(reference.iterations);
            assert!(di <= 1, "shape {shape:?}: iterations {} vs {}", fast.iterations, reference.iterations);
            assert_eq!(fast.converged, reference.converged, "shape {shape:?}");
            if di == 0 {
                assert_eq!(fast.active_spat, reference.active_spat, "shape {shape:?}");
                assert_eq!(fast.active_freq, reference.active_freq, "shape {shape:?}");
            }
            for i in 0..n {
                assert!(
                    (fast.corrected_eps[i] - reference.corrected_eps[i]).abs() < 1e-9,
                    "shape {shape:?} corrected idx {i}"
                );
                assert!(
                    (fast.spat_edits[i] - reference.spat_edits[i]).abs() < 1e-9,
                    "shape {shape:?} spat idx {i}"
                );
            }
            let ff = fast.freq_edits.expand();
            let rf = reference.freq_edits.expand();
            for k in 0..n {
                assert!(
                    (ff[k] - rf[k]).abs() < 1e-10 * (n as f64).sqrt(),
                    "shape {shape:?} freq bin {k}: {:?} vs {:?}",
                    ff[k],
                    rf[k]
                );
            }
            // The fast output satisfies the bounds in its own right.
            let (s_ok, f_ok, ms, mf) = check_dual_bounds(
                &fast.corrected_eps,
                &shape,
                &params.spatial,
                &params.frequency,
            );
            assert!(s_ok && f_ok, "shape {shape:?}: max_s {ms} max_f {mf}");
        }
    }

    /// Threading only changes the execution schedule, never the arithmetic:
    /// results are bit-identical for every thread count.
    #[test]
    fn threaded_projection_is_bit_identical() {
        for shape in [vec![16usize, 16], vec![8, 8, 8], vec![12, 10]] {
            let n: usize = shape.iter().product();
            let eps = random_eps(n, 0.1, 31);
            let base = PocsParams {
                spatial: Bounds::Global(0.1),
                frequency: Bounds::Global(0.25 * 0.1 * (n as f64).sqrt()),
                max_iters: 500,
                threads: 1,
            };
            let r1 = alternating_projection(&eps, &shape, &base);
            for threads in [2usize, 4] {
                let params = PocsParams {
                    threads,
                    ..base.clone()
                };
                let rt = alternating_projection(&eps, &shape, &params);
                assert_eq!(rt.iterations, r1.iterations, "shape {shape:?}");
                assert_eq!(rt.corrected_eps, r1.corrected_eps, "shape {shape:?}");
                assert_eq!(rt.spat_edits, r1.spat_edits, "shape {shape:?}");
                assert_eq!(rt.freq_edits, r1.freq_edits, "shape {shape:?}");
            }
        }
    }
}
