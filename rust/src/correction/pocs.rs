//! Alternating projection onto the s-cube and f-cube (POCS, paper §IV-B).
//!
//! Starting from the spatial error vector `ε = x̂ − x` of the base
//! compressor (inside the s-cube by construction), the loop alternates:
//!
//! 1. `δ = FFT(ε)`; if every component satisfies `|Re δ_k| ≤ Δ_k` and
//!    `|Im δ_k| ≤ Δ_k`, stop — `ε` is in the intersection;
//! 2. project onto the **f-cube** by clipping `δ` componentwise, recording
//!    the displacement as *frequency edits* (along the frequency basis);
//! 3. `ε = IFFT(δ)`; project onto the **s-cube** by clipping `ε` to
//!    `±E_n`, recording the displacement as *spatial edits*.
//!
//! Because the input is real and the per-component bounds are symmetric
//! under Hermitian conjugation, clipping preserves Hermitian symmetry and
//! `ε` stays real throughout (we drop rounding-level imaginary residue).

use crate::fourier::{fftn_inplace, ifftn_inplace, Complex};

/// Per-axis bounds: one global scalar or a full pointwise vector.
#[derive(Debug, Clone)]
pub enum Bounds {
    Global(f64),
    Pointwise(Vec<f64>),
}

impl Bounds {
    #[inline]
    pub fn at(&self, i: usize) -> f64 {
        match self {
            Bounds::Global(b) => *b,
            Bounds::Pointwise(v) => v[i],
        }
    }

    /// Multiply every bound by `f` (used for the quantization shrink).
    pub fn scaled(&self, f: f64) -> Bounds {
        match self {
            Bounds::Global(b) => Bounds::Global(b * f),
            Bounds::Pointwise(v) => Bounds::Pointwise(v.iter().map(|b| b * f).collect()),
        }
    }
}

/// Outcome of the alternating projection.
#[derive(Debug, Clone)]
pub struct PocsResult {
    /// Corrected spatial error vector (real).
    pub corrected_eps: Vec<f64>,
    /// Cumulative spatial edits (length N; sparse in practice).
    pub spat_edits: Vec<f64>,
    /// Cumulative frequency edits (length N complex; sparse in practice).
    pub freq_edits: Vec<Complex>,
    /// Number of loop iterations executed (paper Table III).
    pub iterations: usize,
    /// Whether the loop hit the f-cube constraint before `max_iters`.
    pub converged: bool,
    /// Count of nonzero spatial edits.
    pub active_spat: usize,
    /// Count of frequency components with a nonzero edit.
    pub active_freq: usize,
}

/// Configuration of one projection run.
#[derive(Debug, Clone)]
pub struct PocsParams {
    /// Spatial bounds `E_n` (s-cube half-widths).
    pub spatial: Bounds,
    /// Frequency bounds `Δ_k` applied to Re and Im independently
    /// (f-cube half-widths).
    pub frequency: Bounds,
    /// Iteration cap; the paper observes 1–100 iterations in practice.
    pub max_iters: usize,
}

/// Run the alternating projection on the spatial error vector `eps0` of a
/// row-major field with `shape`.
pub fn alternating_projection(eps0: &[f64], shape: &[usize], params: &PocsParams) -> PocsResult {
    let n = eps0.len();
    debug_assert_eq!(n, shape.iter().product::<usize>());
    let mut eps: Vec<Complex> = eps0.iter().map(|&e| Complex::new(e, 0.0)).collect();
    let mut spat_edits = vec![0.0f64; n];
    let mut freq_edits = vec![Complex::ZERO; n];
    let mut iterations = 0usize;
    let mut converged = false;

    while iterations < params.max_iters {
        iterations += 1;
        // δ = FFT(ε)
        fftn_inplace(&mut eps, shape);

        // Convergence check + f-cube projection fused in one pass. A
        // violation is only *significant* (keeps the loop running) when it
        // exceeds the bound beyond FFT roundoff — without this tolerance
        // the loop can chase 1-ulp exceedances forever. Sub-tolerance
        // exceedances are still clipped (and recorded) before terminating.
        // The Global/Pointwise dispatch is hoisted out of the hot loop.
        let mut violated = false;
        let mut clip_f = |k: usize, d: f64, eps: &mut [Complex]| {
            let v = eps[k];
            let re = v.re.clamp(-d, d);
            let im = v.im.clamp(-d, d);
            if re != v.re || im != v.im {
                if v.linf() > d * (1.0 + 1e-10) {
                    violated = true;
                }
                let clipped = Complex::new(re, im);
                freq_edits[k] += clipped - v;
                eps[k] = clipped;
            }
        };
        match &params.frequency {
            Bounds::Global(d) => {
                let d = *d;
                for k in 0..n {
                    clip_f(k, d, &mut eps);
                }
            }
            Bounds::Pointwise(v) => {
                for k in 0..n {
                    clip_f(k, v[k], &mut eps);
                }
            }
        }
        if !violated {
            // Already inside the f-cube: undo the transform and stop.
            ifftn_inplace(&mut eps, shape);
            converged = true;
            break;
        }

        // Back to the spatial basis.
        ifftn_inplace(&mut eps, shape);

        // s-cube projection (drop rounding-level imaginary residue).
        let mut clip_s = |i: usize, e: f64, eps: &mut [Complex]| {
            let v = eps[i].re;
            let clipped = v.clamp(-e, e);
            if clipped != v {
                spat_edits[i] += clipped - v;
            }
            eps[i] = Complex::new(clipped, 0.0);
        };
        match &params.spatial {
            Bounds::Global(e) => {
                let e = *e;
                for i in 0..n {
                    clip_s(i, e, &mut eps);
                }
            }
            Bounds::Pointwise(v) => {
                for i in 0..n {
                    clip_s(i, v[i], &mut eps);
                }
            }
        }
    }

    let corrected_eps: Vec<f64> = eps.iter().map(|c| c.re).collect();
    let active_spat = spat_edits.iter().filter(|&&e| e != 0.0).count();
    let active_freq = freq_edits
        .iter()
        .filter(|c| c.re != 0.0 || c.im != 0.0)
        .count();
    PocsResult {
        corrected_eps,
        spat_edits,
        freq_edits,
        iterations,
        converged,
        active_spat,
        active_freq,
    }
}

/// Check the dual-domain constraints for an error vector (used by tests and
/// the archive verifier). Returns `(spatial_ok, frequency_ok, max_spat,
/// max_freq_linf)` where the maxima are normalized by their bound (≤ 1 is
/// in-bound).
pub fn check_dual_bounds(
    eps: &[f64],
    shape: &[usize],
    spatial: &Bounds,
    frequency: &Bounds,
) -> (bool, bool, f64, f64) {
    let mut max_s = 0.0f64;
    for (i, &e) in eps.iter().enumerate() {
        let b = spatial.at(i);
        let r = if b > 0.0 { e.abs() / b } else if e == 0.0 { 0.0 } else { f64::INFINITY };
        max_s = max_s.max(r);
    }
    let mut delta: Vec<Complex> = eps.iter().map(|&e| Complex::new(e, 0.0)).collect();
    fftn_inplace(&mut delta, shape);
    let mut max_f = 0.0f64;
    for (k, d) in delta.iter().enumerate() {
        let b = frequency.at(k);
        let linf = d.linf();
        let r = if b > 0.0 { linf / b } else if linf == 0.0 { 0.0 } else { f64::INFINITY };
        max_f = max_f.max(r);
    }
    // Tiny tolerance for FFT roundoff in the *verifier* (the projector
    // itself clips hard).
    (max_s <= 1.0 + 1e-9, max_f <= 1.0 + 1e-9, max_s, max_f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;

    fn random_eps(n: usize, e: f64, seed: u64) -> Vec<f64> {
        let mut rng = XorShift::new(seed);
        (0..n).map(|_| rng.uniform(-e, e)).collect()
    }

    #[test]
    fn already_feasible_terminates_in_one_iteration() {
        // Huge Δ ⇒ f-cube contains everything the s-cube can produce.
        let n = 64;
        let eps = random_eps(n, 0.1, 1);
        let params = PocsParams {
            spatial: Bounds::Global(0.1),
            frequency: Bounds::Global(1e6),
            max_iters: 100,
        };
        let r = alternating_projection(&eps, &[n], &params);
        assert!(r.converged);
        assert_eq!(r.iterations, 1);
        assert_eq!(r.active_spat, 0);
        assert_eq!(r.active_freq, 0);
        for (a, b) in r.corrected_eps.iter().zip(&eps) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn dual_bounds_hold_after_projection() {
        for seed in 0..5u64 {
            let n = 128;
            let e = 0.05;
            let eps = random_eps(n, e, seed);
            // Tight frequency bound forces actual work.
            let delta = 0.2;
            let params = PocsParams {
                spatial: Bounds::Global(e),
                frequency: Bounds::Global(delta),
                max_iters: 500,
            };
            let r = alternating_projection(&eps, &[n], &params);
            assert!(r.converged, "seed {seed} did not converge");
            let (s_ok, f_ok, ms, mf) = check_dual_bounds(
                &r.corrected_eps,
                &[n],
                &params.spatial,
                &params.frequency,
            );
            assert!(s_ok && f_ok, "seed {seed}: max_s {ms} max_f {mf}");
        }
    }

    #[test]
    fn edits_reconstruct_the_correction() {
        // corrected ε == ε₀ + spat_edits + IFFT(freq_edits): the two edit
        // streams fully describe the correction (paper §IV-B "applying
        // edits").
        let n = 64;
        let eps = random_eps(n, 0.1, 7);
        let params = PocsParams {
            spatial: Bounds::Global(0.1),
            frequency: Bounds::Global(0.3),
            max_iters: 500,
        };
        let r = alternating_projection(&eps, &[n], &params);
        let mut freq_part = r.freq_edits.clone();
        ifftn_inplace(&mut freq_part, &[n]);
        for i in 0..n {
            let rebuilt = eps[i] + r.spat_edits[i] + freq_part[i].re;
            assert!(
                (rebuilt - r.corrected_eps[i]).abs() < 1e-10,
                "i={i}: {rebuilt} vs {}",
                r.corrected_eps[i]
            );
        }
    }

    #[test]
    fn tiny_delta_clips_everything_first_pass() {
        // Paper Table III: very small Δ ⇒ f-cube inside s-cube ⇒ massive
        // frequency clipping but zero *spatial* edits, 1–2 iterations.
        let n = 256;
        let eps = random_eps(n, 0.1, 3);
        let params = PocsParams {
            spatial: Bounds::Global(0.1),
            frequency: Bounds::Global(1e-6),
            max_iters: 50,
        };
        let r = alternating_projection(&eps, &[n], &params);
        assert!(r.converged);
        assert!(r.active_freq > n / 2, "freq edits {}", r.active_freq);
        assert!(r.iterations <= 3, "iterations {}", r.iterations);
    }

    #[test]
    fn pointwise_bounds_respected() {
        let n = 32;
        let eps = random_eps(n, 0.2, 9);
        let spat: Vec<f64> = (0..n).map(|i| 0.05 + 0.01 * (i % 5) as f64).collect();
        let freq: Vec<f64> = (0..n)
            .map(|k| if k % 2 == 0 { 0.5 } else { 0.1 })
            .collect();
        let params = PocsParams {
            spatial: Bounds::Pointwise(spat.clone()),
            frequency: Bounds::Pointwise(freq.clone()),
            max_iters: 1000,
        };
        // Start inside the s-cube: clip the input first.
        let eps: Vec<f64> = eps
            .iter()
            .enumerate()
            .map(|(i, &e)| e.clamp(-spat[i], spat[i]))
            .collect();
        let r = alternating_projection(&eps, &[n], &params);
        assert!(r.converged);
        let (s_ok, f_ok, ..) = check_dual_bounds(
            &r.corrected_eps,
            &[n],
            &params.spatial,
            &params.frequency,
        );
        assert!(s_ok && f_ok);
    }

    #[test]
    fn works_in_2d_and_3d() {
        for shape in [vec![16usize, 16], vec![8, 8, 8]] {
            let n: usize = shape.iter().product();
            let eps = random_eps(n, 0.1, 11);
            let params = PocsParams {
                spatial: Bounds::Global(0.1),
                frequency: Bounds::Global(0.4),
                max_iters: 500,
            };
            let r = alternating_projection(&eps, &shape, &params);
            assert!(r.converged, "shape {shape:?}");
            let (s_ok, f_ok, ..) =
                check_dual_bounds(&r.corrected_eps, &shape, &params.spatial, &params.frequency);
            assert!(s_ok && f_ok, "shape {shape:?}");
        }
    }

    #[test]
    fn hermitian_symmetry_keeps_eps_real() {
        // After many iterations the imaginary residue must stay at rounding
        // level — checked implicitly by corrected_eps being the full state.
        let n = 100; // non-pow2 exercises Bluestein too
        let eps = random_eps(n, 0.1, 13);
        let params = PocsParams {
            spatial: Bounds::Global(0.1),
            frequency: Bounds::Global(0.25),
            max_iters: 400,
        };
        let r = alternating_projection(&eps, &[n], &params);
        assert!(r.converged);
        // Feed the corrected ε back: it must already be feasible (fixpoint).
        let r2 = alternating_projection(&r.corrected_eps, &[n], &params);
        assert_eq!(r2.iterations, 1);
        assert!(r2.converged);
    }
}
