//! FFCz: dual-domain error-bounded correction on top of a base compressor
//! (the paper's core contribution, §IV).
//!
//! [`compress`] runs the base compressor, measures its spatial error
//! vector, drives it into the intersection of the s-cube and f-cube by
//! [`pocs::alternating_projection`], and stores the resulting sparse edits
//! (quantized + entropy-coded) next to the base payload in an
//! [`FfczArchive`]. [`decompress`] reverses this; [`verify`] checks the
//! dual-domain guarantee.
//!
//! Quantization is *validated, not assumed*: after quantizing the edits the
//! encoder re-checks both bounds against the dequantized edits and retries
//! with a larger bound shrink (or falls back to raw f64 edits) if the
//! guarantee would be violated — so every archive that leaves this module
//! satisfies the user's bounds exactly.
//!
//! The whole encode path — bound resolution, every projection attempt,
//! every quantization re-check, the final archive verification — runs
//! through a [`CorrectionScratch`]: shared plan handles plus grow-only
//! transform buffers, threaded from [`correct_reconstruction`] down into
//! the POCS entry points. Batch encoders hold one scratch per worker (the
//! store) or per stage thread (the pipeline); after warm-up on a chunk
//! shape the steady-state encode performs zero scratch allocations, and
//! scratch-reusing encodes are bit-identical to fresh-state ones.

pub mod apply;
pub mod edits;
pub mod pocs;
pub mod scratch;

use anyhow::{bail, Result};

use crate::compressors::{Compressor, ErrorBound};
use crate::data::Field;
use crate::encoding::{fixed, lossless_compress, lossless_decompress, varint};
use crate::fourier::{for_each_full_bin, half_index_of, Complex};

pub use edits::{PointwiseQuantizedEdits, QuantizedComplexEdits, QuantizedEdits, QUANT_BITS};
pub use pocs::{
    alternating_projection, alternating_projection_reference,
    alternating_projection_with_scratch, check_dual_bounds, check_dual_bounds_with_scratch,
    Bounds, PocsParams, PocsResult,
};
pub use scratch::CorrectionScratch;

/// How a bound is specified.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BoundSpec {
    /// Absolute half-width.
    Absolute(f64),
    /// Spatial: relative to the field's value span. Frequency: relative to
    /// the max frequency-component magnitude `max_k |X_k|` (the RFE
    /// denominator, §V-A).
    Relative(f64),
}

/// Frequency-domain bound modes.
#[derive(Debug, Clone, PartialEq)]
pub enum FrequencyBound {
    /// One bound Δ applied to Re and Im of every component (Eq. 2).
    Uniform(BoundSpec),
    /// Fig. 10 mode: per-component bounds `Δ_k ∝ |X_k|` chosen so that
    /// every power-spectrum bin's relative error is ≤ the given fraction.
    PowerSpectrumRelative(f64),
}

/// Full FFCz configuration.
#[derive(Debug, Clone)]
pub struct FfczConfig {
    /// Spatial bound E.
    pub spatial: BoundSpec,
    /// Frequency bound Δ (uniform or power-spectrum-derived).
    pub frequency: FrequencyBound,
    /// POCS iteration cap.
    pub max_iters: usize,
    /// Bound-shrink retry ladder for quantization (see module docs).
    pub max_quant_retries: usize,
    /// OS threads for the N-D line transforms inside the POCS loop. An
    /// *execution* knob, not codec identity: the correction (and the
    /// archive bytes) are bit-identical for every value, so it is never
    /// serialized into specs or manifests. `0` (the default) means
    /// **auto**: the store writer budgets it cooperatively as
    /// `available_parallelism() / workers`, so per-chunk line threading
    /// and the cross-chunk worker pool compose without oversubscription;
    /// direct (whole-field) correction runs resolve auto to one thread.
    /// Explicit values ([`FfczConfig::with_threads`], `--threads`, the
    /// `threads=` chunk-codec key) always win over auto.
    pub threads: usize,
}

impl FfczConfig {
    /// Relative bounds in both domains (the paper's usual setting).
    pub fn relative(spatial: f64, frequency: f64) -> Self {
        Self {
            spatial: BoundSpec::Relative(spatial),
            frequency: FrequencyBound::Uniform(BoundSpec::Relative(frequency)),
            max_iters: 200,
            max_quant_retries: 3,
            threads: 0,
        }
    }

    /// Absolute bounds in both domains.
    pub fn absolute(spatial: f64, frequency: f64) -> Self {
        Self {
            spatial: BoundSpec::Absolute(spatial),
            frequency: FrequencyBound::Uniform(BoundSpec::Absolute(frequency)),
            max_iters: 200,
            max_quant_retries: 3,
            threads: 0,
        }
    }

    /// Power-spectrum preservation mode (Fig. 10): relative spatial bound
    /// plus a relative bound on every power-spectrum bin.
    pub fn power_spectrum(spatial_rel: f64, spectrum_rel: f64) -> Self {
        Self {
            spatial: BoundSpec::Relative(spatial_rel),
            frequency: FrequencyBound::PowerSpectrumRelative(spectrum_rel),
            max_iters: 200,
            max_quant_retries: 3,
            threads: 0,
        }
    }

    /// Set an explicit POCS transform thread count (builder style). The
    /// count is clamped to ≥ 1 — auto-budgeting is requested by *leaving*
    /// `threads` at its default of 0, not by setting it.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }
}

/// Bounds resolved against a concrete field.
#[derive(Debug, Clone)]
pub struct ResolvedBounds {
    pub spatial: Bounds,
    pub frequency: Bounds,
    /// For pointwise frequency bounds: the `(r, floor)` rule used to build
    /// `Δ_k = max(r·|X_k|/√2, floor)` — reused (against the *base
    /// reconstruction's* spectrum) as the spectral quantization step rule.
    pub spectral_rule: Option<(f64, f64)>,
}

/// Resolve the configured bounds against the original field. Frequency
/// bounds need the original's FFT for `Relative` and `PowerSpectrum`
/// modes; plan and transform scratch are built per call — the encode hot
/// path reuses them through [`resolve_bounds_with_scratch`].
pub fn resolve_bounds(field: &Field, cfg: &FfczConfig) -> ResolvedBounds {
    resolve_bounds_with_scratch(field, cfg, &mut CorrectionScratch::new())
}

/// [`resolve_bounds`] with caller-owned transform state: the bound
/// resolution's forward transform runs through `scratch`'s plan handle,
/// workspace, and spectrum buffer.
pub fn resolve_bounds_with_scratch(
    field: &Field,
    cfg: &FfczConfig,
    scratch: &mut CorrectionScratch,
) -> ResolvedBounds {
    let e = match cfg.spatial {
        BoundSpec::Absolute(v) => v,
        BoundSpec::Relative(r) => ErrorBound::Relative(r).absolute_for(field),
    };
    let spatial = Bounds::Global(e);
    let mut spectral_rule = None;
    let frequency = match &cfg.frequency {
        FrequencyBound::Uniform(BoundSpec::Absolute(v)) => Bounds::Global(*v),
        FrequencyBound::Uniform(BoundSpec::Relative(r)) => {
            // max_k |X_k| over the half spectrum equals the full-lattice
            // max (conjugation preserves magnitude).
            let spec = half_spectrum_into_scratch(field, scratch);
            let max_mag = spec.iter().map(|c| c.abs()).fold(0.0f64, f64::max);
            Bounds::Global(r * max_mag.max(f64::MIN_POSITIVE))
        }
        FrequencyBound::PowerSpectrumRelative(p) => {
            // Per-component bound Δ_k = r·|X_k|/√2 with r = √(1+p') − 1:
            // |δ_k| ≤ √2·Δ_k ≤ r|X_k| ⇒ ||X̂|²−|X|²| ≤ (2r+r²)|X|² = p'|X|²
            // per mode, hence ≤ p'·P(k) per shell. p' = 0.9p leaves headroom
            // for the mean-normalization shift of the measured spectrum
            // (P(k) divides by the reconstructed mean, which moves by the
            // DC error). The DC component itself is pinned to the floor
            // bound so the mean shift is negligible; zero/near-zero modes
            // get the same floor so the f-cube stays satisfiable.
            //
            // Built from the half spectrum: mirrored bins read the same
            // stored magnitude, so `Δ_{−k} == Δ_k` holds *exactly* — which
            // is what keeps the POCS fast path on the half spectrum.
            let spec = half_spectrum_into_scratch(field, scratch);
            let r = (1.0 + 0.9 * p).sqrt() - 1.0;
            let max_mag = spec.iter().map(|c| c.abs()).fold(0.0f64, f64::max);
            let floor = r * 1e-4 * max_mag.max(f64::MIN_POSITIVE);
            let mut per = vec![0.0f64; field.len()];
            for_each_full_bin(field.shape(), |full, half, _conj| {
                per[full] = (r * spec[half].abs() / std::f64::consts::SQRT_2).max(floor);
            });
            per[0] = floor; // pin DC: preserve the mean
            spectral_rule = Some((r, floor));
            Bounds::Pointwise(per)
        }
    };
    ResolvedBounds {
        spatial,
        frequency,
        spectral_rule,
    }
}

/// Half spectrum of the original (real) field, transformed into the
/// scratch's primary spectrum buffer (no allocation once warmed) — the
/// bound-resolution transform at half the cost of the full `fftn` it
/// replaced.
fn half_spectrum_into_scratch<'a>(
    field: &Field,
    scratch: &'a mut CorrectionScratch,
) -> &'a [Complex] {
    let plan = scratch.plan(field.shape());
    let h = plan.half_len();
    scratch.ensure_spec(h);
    let CorrectionScratch { spec, ws, .. } = scratch;
    let spec = &mut spec[..h];
    plan.forward(field.data(), spec, 1, ws);
    spec
}

/// Stored edit payload: quantized in the common case (with an optional
/// sparse raw *patch* for components whose quantization error would break
/// a pointwise bound), raw f64 sparse as a guaranteed-correct fallback.
#[derive(Debug, Clone, PartialEq)]
pub enum EditsBlock {
    Quantized {
        spat: QuantizedEdits,
        freq: QuantizedComplexEdits,
        /// Exact frequency-domain corrections `(k, re, im)` *added on top*
        /// of the dequantized freq edits.
        patch: Vec<(u32, f64, f64)>,
    },
    /// Pointwise-bound mode: frequency edits with per-component steps tied
    /// to the local bound (see `PointwiseQuantizedEdits`).
    PointwiseQuantized {
        spat: QuantizedEdits,
        freq: PointwiseQuantizedEdits,
    },
    Raw {
        n: usize,
        spat: Vec<(u32, f64)>,
        freq: Vec<(u32, f64, f64)>,
    },
}

impl EditsBlock {
    /// Dense (spatial, frequency) edit vectors.
    pub fn dense(&self) -> (Vec<f64>, Vec<Complex>) {
        match self {
            EditsBlock::Quantized { spat, freq, patch } => {
                let s = spat.dequantize();
                let mut f = freq.dequantize();
                for &(i, re, im) in patch {
                    f[i as usize] += Complex::new(re, im);
                }
                (s, f)
            }
            EditsBlock::PointwiseQuantized { spat, freq } => {
                (spat.dequantize(), freq.dequantize())
            }
            EditsBlock::Raw { n, spat, freq } => {
                let mut s = vec![0.0f64; *n];
                for &(i, v) in spat {
                    s[i as usize] = v;
                }
                let mut f = vec![Complex::ZERO; *n];
                for &(i, re, im) in freq {
                    f[i as usize] = Complex::new(re, im);
                }
                (s, f)
            }
        }
    }

    /// Scatter the Hermitian fold of this block's (conceptual) dense
    /// frequency edit vector straight into the half-layout buffer `out`
    /// (length [`crate::fourier::half_len`] of `shape`; zeroed here),
    /// touching only stored bins.
    ///
    /// Bit-identical to `fold_full_into(&self.dense().1, shape, out)`
    /// without materializing the dense vector: every edit stream is
    /// exactly conjugate-symmetric (the quantizers grid the *expanded*
    /// Hermitian vector with symmetric rounding, patch entries are pushed
    /// in conjugate mirror pairs by the full-bin walk, raw edits come from
    /// `HalfSpectrum::expand`), so the fold at a canonical bin computes
    /// `(v + conj(conj v)) · ½ = v` exactly in IEEE arithmetic, and at a
    /// self-conjugate bin `(v + conj v) · ½` — the real part unchanged,
    /// the imaginary part an exact `+0.0`. Scattering only the canonical
    /// entries (and dropping imaginary contributions at self-conjugate
    /// bins) reproduces precisely that. The regression test
    /// `sparse_fold_scatter_matches_dense_reference` pins the equivalence
    /// bitwise per variant.
    fn scatter_freq_folded(&self, shape: &[usize], out: &mut [Complex]) {
        for c in out.iter_mut() {
            *c = Complex::ZERO;
        }
        match self {
            EditsBlock::Quantized { freq, patch, .. } => {
                for (&i, &g) in freq.re.idx.iter().zip(&freq.re.q) {
                    if let Some((half, _)) = half_index_of(shape, i as usize) {
                        out[half].re = g as f64 * freq.re.step;
                    }
                }
                for (&i, &g) in freq.im.idx.iter().zip(&freq.im.q) {
                    if let Some((half, self_conj)) = half_index_of(shape, i as usize) {
                        if !self_conj {
                            out[half].im = g as f64 * freq.im.step;
                        }
                    }
                }
                // The patch *adds* on top of the dequantized planes, in
                // stream order — same association as `dense()`.
                for &(i, re, im) in patch {
                    if let Some((half, self_conj)) = half_index_of(shape, i as usize) {
                        out[half].re += re;
                        if !self_conj {
                            out[half].im += im;
                        }
                    }
                }
            }
            EditsBlock::PointwiseQuantized { freq, .. } => {
                for (((&k, &e), &gr), &gi) in freq
                    .idx
                    .iter()
                    .zip(&freq.step_exp)
                    .zip(&freq.q_re)
                    .zip(&freq.q_im)
                {
                    if let Some((half, self_conj)) = half_index_of(shape, k as usize) {
                        let s = freq.base_step * (2.0f64).powi(e as i32);
                        out[half].re = gr as f64 * s;
                        if !self_conj {
                            out[half].im = gi as f64 * s;
                        }
                    }
                }
            }
            EditsBlock::Raw { freq, .. } => {
                for &(i, re, im) in freq {
                    if let Some((half, self_conj)) = half_index_of(shape, i as usize) {
                        out[half].re = re;
                        if !self_conj {
                            out[half].im = im;
                        }
                    }
                }
            }
        }
    }

    /// `out[i] += eps0[i] + spat[i]` for every `i`, streaming the sparse
    /// ascending spatial index list instead of materializing the dense
    /// `spat` vector. Bit-identical to the dense form: absent entries
    /// contribute an exact `+ 0.0`, matching the zero-initialized dense
    /// vector, and present entries contribute the identical dequantized
    /// value in the identical `eps0[i] + s` association.
    fn add_eps0_and_spat(&self, eps0: &[f64], out: &mut [f64]) {
        match self {
            EditsBlock::Quantized { spat, .. } | EditsBlock::PointwiseQuantized { spat, .. } => {
                let mut p = 0usize;
                for i in 0..out.len() {
                    let s = if p < spat.idx.len() && spat.idx[p] as usize == i {
                        let v = spat.q[p] as f64 * spat.step;
                        p += 1;
                        v
                    } else {
                        0.0
                    };
                    out[i] += eps0[i] + s;
                }
            }
            EditsBlock::Raw { spat, .. } => {
                let mut p = 0usize;
                for i in 0..out.len() {
                    let s = if p < spat.len() && spat[p].0 as usize == i {
                        let v = spat[p].1;
                        p += 1;
                        v
                    } else {
                        0.0
                    };
                    out[i] += eps0[i] + s;
                }
            }
        }
    }

    pub fn active_counts(&self) -> (usize, usize) {
        match self {
            EditsBlock::Quantized { spat, freq, patch } => {
                (spat.active(), freq.active() + patch.len())
            }
            EditsBlock::PointwiseQuantized { spat, freq } => (spat.active(), freq.active()),
            EditsBlock::Raw { spat, freq, .. } => (spat.len(), freq.len()),
        }
    }

    fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            EditsBlock::Quantized { spat, freq, patch } => {
                out.push(0u8);
                out.extend_from_slice(&spat.to_bytes());
                out.extend_from_slice(&freq.to_bytes());
                varint::write(&mut out, patch.len() as u64);
                for &(i, re, im) in patch {
                    varint::write(&mut out, i as u64);
                    out.extend_from_slice(&re.to_le_bytes());
                    out.extend_from_slice(&im.to_le_bytes());
                }
            }
            EditsBlock::PointwiseQuantized { spat, freq } => {
                out.push(2u8);
                out.extend_from_slice(&spat.to_bytes());
                out.extend_from_slice(&freq.to_bytes());
            }
            EditsBlock::Raw { n, spat, freq } => {
                out.push(1u8);
                let mut raw = Vec::new();
                varint::write(&mut raw, *n as u64);
                varint::write(&mut raw, spat.len() as u64);
                for &(i, v) in spat {
                    varint::write(&mut raw, i as u64);
                    raw.extend_from_slice(&v.to_le_bytes());
                }
                varint::write(&mut raw, freq.len() as u64);
                for &(i, re, im) in freq {
                    varint::write(&mut raw, i as u64);
                    raw.extend_from_slice(&re.to_le_bytes());
                    raw.extend_from_slice(&im.to_le_bytes());
                }
                let enc = lossless_compress(&raw);
                varint::write(&mut out, enc.len() as u64);
                out.extend_from_slice(&enc);
            }
        }
        out
    }

    fn from_bytes(buf: &[u8], pos: &mut usize) -> Result<Self> {
        if *pos >= buf.len() {
            bail!("truncated edits block");
        }
        let tag = buf[*pos];
        *pos += 1;
        match tag {
            0 => {
                let spat = QuantizedEdits::from_bytes(buf, pos)?;
                let freq = QuantizedComplexEdits::from_bytes(buf, pos)?;
                let n_patch = varint::read(buf, pos)? as usize;
                let mut patch = Vec::with_capacity(n_patch);
                for _ in 0..n_patch {
                    let i = varint::read(buf, pos)? as u32;
                    let re = fixed::read_f64_le(buf, pos, "patch real part")?;
                    let im = fixed::read_f64_le(buf, pos, "patch imaginary part")?;
                    patch.push((i, re, im));
                }
                Ok(EditsBlock::Quantized { spat, freq, patch })
            }
            1 => {
                let len = varint::read(buf, pos)? as usize;
                if *pos + len > buf.len() {
                    bail!("truncated raw edits");
                }
                let raw = lossless_decompress(&buf[*pos..*pos + len])?;
                *pos += len;
                let mut rp = 0usize;
                let n = varint::read(&raw, &mut rp)? as usize;
                let ns = varint::read(&raw, &mut rp)? as usize;
                let mut spat = Vec::with_capacity(ns);
                for _ in 0..ns {
                    let i = varint::read(&raw, &mut rp)? as u32;
                    let v = fixed::read_f64_le(&raw, &mut rp, "raw spat edit")?;
                    spat.push((i, v));
                }
                let nf = varint::read(&raw, &mut rp)? as usize;
                let mut freq = Vec::with_capacity(nf);
                for _ in 0..nf {
                    let i = varint::read(&raw, &mut rp)? as u32;
                    let re = fixed::read_f64_le(&raw, &mut rp, "raw freq edit real part")?;
                    let im = fixed::read_f64_le(&raw, &mut rp, "raw freq edit imaginary part")?;
                    freq.push((i, re, im));
                }
                Ok(EditsBlock::Raw { n, spat, freq })
            }
            2 => {
                let spat = QuantizedEdits::from_bytes(buf, pos)?;
                let freq = PointwiseQuantizedEdits::from_bytes(buf, pos)?;
                Ok(EditsBlock::PointwiseQuantized { spat, freq })
            }
            x => bail!("unknown edits tag {x}"),
        }
    }
}

/// Statistics recorded during correction (drives Tables III/IV rows).
#[derive(Debug, Clone, Default)]
pub struct CorrectionStats {
    pub iterations: usize,
    pub converged: bool,
    pub active_spat: usize,
    pub active_freq: usize,
    pub quant_attempts: usize,
    pub used_raw_fallback: bool,
}

/// A complete FFCz archive: base payload + edits + metadata.
#[derive(Debug, Clone)]
pub struct FfczArchive {
    pub base_name: String,
    pub base_payload: Vec<u8>,
    pub edits: EditsBlock,
    pub stats: CorrectionStats,
}

impl FfczArchive {
    pub fn base_bytes(&self) -> usize {
        self.base_payload.len()
    }

    pub fn edit_bytes(&self) -> usize {
        self.edits.to_bytes().len()
    }

    /// Total serialized size.
    pub fn total_bytes(&self) -> usize {
        self.to_bytes().len()
    }

    /// Serialize to a self-describing byte buffer.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(b"FFCZ1");
        varint::write(&mut out, self.base_name.len() as u64);
        out.extend_from_slice(self.base_name.as_bytes());
        varint::write(&mut out, self.base_payload.len() as u64);
        out.extend_from_slice(&self.base_payload);
        out.extend_from_slice(&self.edits.to_bytes());
        // Footer stats.
        varint::write(&mut out, self.stats.iterations as u64);
        out.push(self.stats.converged as u8);
        varint::write(&mut out, self.stats.active_spat as u64);
        varint::write(&mut out, self.stats.active_freq as u64);
        out
    }

    /// Inverse of [`FfczArchive::to_bytes`].
    pub fn from_bytes(buf: &[u8]) -> Result<Self> {
        if buf.len() < 5 || &buf[..5] != b"FFCZ1" {
            bail!("not an FFCz archive");
        }
        let mut pos = 5usize;
        let name_len = varint::read(buf, &mut pos)? as usize;
        if pos + name_len > buf.len() {
            bail!("truncated name");
        }
        let base_name = String::from_utf8(buf[pos..pos + name_len].to_vec())?;
        pos += name_len;
        let plen = varint::read(buf, &mut pos)? as usize;
        if pos + plen > buf.len() {
            bail!("truncated base payload");
        }
        let base_payload = buf[pos..pos + plen].to_vec();
        pos += plen;
        let edits = EditsBlock::from_bytes(buf, &mut pos)?;
        let used_raw_fallback = matches!(edits, EditsBlock::Raw { .. });
        let iterations = varint::read(buf, &mut pos)? as usize;
        if pos >= buf.len() {
            bail!("truncated footer");
        }
        let converged = buf[pos] != 0;
        pos += 1;
        let active_spat = varint::read(buf, &mut pos)? as usize;
        let active_freq = varint::read(buf, &mut pos)? as usize;
        Ok(Self {
            base_name,
            base_payload,
            edits,
            stats: CorrectionStats {
                iterations,
                converged,
                active_spat,
                active_freq,
                quant_attempts: 0,
                used_raw_fallback,
            },
        })
    }
}

/// Compress `field` with `base` and correct it to satisfy `cfg`'s dual
/// bounds. The returned archive decompresses to a reconstruction bounded in
/// both domains.
pub fn compress(field: &Field, base: &dyn Compressor, cfg: &FfczConfig) -> Result<FfczArchive> {
    let bound = match cfg.spatial {
        BoundSpec::Absolute(v) => ErrorBound::Absolute(v),
        BoundSpec::Relative(r) => ErrorBound::Relative(r),
    };
    let base_payload = base.compress(field, bound)?;
    let recon0 = base.decompress(&base_payload)?;
    correct_reconstruction(field, &recon0, base.name(), base_payload, cfg)
}

/// Correct an existing base-compressor reconstruction (the "edit" step in
/// isolation — what the paper's throughput plots time). Plan handles and
/// transform workspace are built per call; batch encoders (the store's
/// chunk workers, the pipeline's edit stage) thread one
/// [`CorrectionScratch`] through
/// [`correct_reconstruction_with_scratch`] instead, so the whole retry
/// ladder — projection, quantization re-checks, patch transform — reuses
/// one warmed set of buffers per worker.
pub fn correct_reconstruction(
    field: &Field,
    recon0: &Field,
    base_name: &str,
    base_payload: Vec<u8>,
    cfg: &FfczConfig,
) -> Result<FfczArchive> {
    correct_reconstruction_with_scratch(
        field,
        recon0,
        base_name,
        base_payload,
        cfg,
        &mut CorrectionScratch::new(),
    )
}

/// [`correct_reconstruction`] with caller-owned transform state. After the
/// scratch has warmed up on a chunk shape, further chunks of that shape
/// encode with zero scratch allocations
/// ([`CorrectionScratch::allocation_events`] is the gauge); archives are
/// bit-identical to fresh-state encoding (property-tested).
pub fn correct_reconstruction_with_scratch(
    field: &Field,
    recon0: &Field,
    base_name: &str,
    base_payload: Vec<u8>,
    cfg: &FfczConfig,
    scratch: &mut CorrectionScratch,
) -> Result<FfczArchive> {
    let bounds = resolve_bounds_with_scratch(field, cfg, scratch);
    let eps0: Vec<f64> = recon0
        .data()
        .iter()
        .zip(field.data())
        .map(|(r, x)| r - x)
        .collect();
    let shape = field.shape();

    // Quantization shrink ladder: m-bit shrink first (the paper's
    // `1 − 2⁻ᵐ`), then progressively coarser safety margins. Pointwise
    // mode starts coarse on purpose: its per-component quantization steps
    // are `Δ_k·(1−shrink)/2`, and a coarser shrink shortens every stored
    // grid index by ~12 bits at the cost of a few-percent-tighter f-cube.
    let shrinks: [f64; 4] = if matches!(bounds.frequency, Bounds::Pointwise(_)) {
        [
            1.0 - (2.0f64).powi(-4),
            1.0 - (2.0f64).powi(-3),
            1.0 - (2.0f64).powi(-2),
            0.5,
        ]
    } else {
        [
            1.0 - (2.0f64).powi(-(QUANT_BITS as i32)),
            1.0 - (2.0f64).powi(-10),
            1.0 - (2.0f64).powi(-6),
            1.0 - (2.0f64).powi(-4),
        ]
    };
    let attempts = cfg.max_quant_retries.clamp(1, shrinks.len());

    let mut stats = CorrectionStats::default();
    let mut chosen: Option<(EditsBlock, PocsResult)> = None;
    for (attempt, &shrink) in shrinks.iter().take(attempts).enumerate() {
        let params = PocsParams {
            spatial: bounds.spatial.scaled(shrink),
            frequency: bounds.frequency.scaled(shrink),
            max_iters: cfg.max_iters,
            threads: cfg.threads,
        };
        let result = alternating_projection_with_scratch(&eps0, shape, &params, scratch);
        stats.quant_attempts = attempt + 1;
        if !result.converged {
            // Non-intersecting cubes within the iteration cap: surface it.
            bail!(
                "POCS did not converge in {} iterations — the requested \
                 bounds may be unsatisfiable (s-cube ∩ f-cube ≈ ∅)",
                cfg.max_iters
            );
        }
        let spat_q = QuantizedEdits::quantize(&result.spat_edits);
        // The projector keeps frequency edits in half-spectrum layout; the
        // quantizers expand to the full Hermitian vector here — once, at
        // the cold coding boundary — so the stored stream (and the archive
        // bytes) are unchanged.
        let block = if matches!(bounds.frequency, Bounds::Pointwise(_)) {
            // Pointwise bounds: per-component steps a factor `gap` below
            // each Δ_k, so quantization error stays inside this attempt's
            // shrink margin.
            let gap = (1.0 - shrink) / 2.0;
            let fb = &bounds.frequency;
            EditsBlock::PointwiseQuantized {
                spat: spat_q.clone(),
                freq: PointwiseQuantizedEdits::quantize_half(
                    &result.freq_edits,
                    |k| fb.at(k),
                    gap,
                ),
            }
        } else {
            EditsBlock::Quantized {
                spat: spat_q.clone(),
                freq: QuantizedComplexEdits::quantize_half(&result.freq_edits),
                patch: Vec::new(),
            }
        };
        if edits_satisfy_bounds(&eps0, &block, shape, &bounds, cfg.threads, scratch) {
            stats.iterations = result.iterations;
            stats.converged = true;
            chosen = Some((block, result));
            break;
        }
        // Quantization leaked past a (typically pointwise) frequency bound.
        // Instead of abandoning quantization wholesale, patch exactly the
        // violating components with raw corrections: clip δ of the
        // quantized reconstruction at those k back inside the (shrunk)
        // f-cube. The patch is a frequency-basis move, so the spatial
        // domain shifts by ≤ Σ|patch|/N — absorbed by the shrink margin
        // and re-verified before committing.
        if let EditsBlock::Quantized { freq: freq_q, .. } = &block {
            let eps_q = apply::corrected_eps_with_scratch(&eps0, &block, shape, scratch);
            let target = bounds.frequency.scaled(shrink);
            let mut patch_list: Vec<(u32, f64, f64)> = Vec::new();
            {
                // δ of the (real) quantized error vector, via the half
                // spectrum in scratch; mirror bins are read conjugated.
                let plan = scratch.plan(shape);
                let h_total = plan.half_len();
                scratch.ensure_spec(h_total);
                let CorrectionScratch { spec, ws, .. } = scratch;
                let spec = &mut spec[..h_total];
                plan.forward(&eps_q, spec, cfg.threads.max(1), ws);
                for_each_full_bin(shape, |full, half, conj| {
                    let stored = spec[half];
                    let d = if conj { stored.conj() } else { stored };
                    if d.linf() > bounds.frequency.at(full) {
                        let t = target.at(full);
                        let re = d.re.clamp(-t, t) - d.re;
                        let im = d.im.clamp(-t, t) - d.im;
                        patch_list.push((full as u32, re, im));
                    }
                });
            }
            // Patching only pays off while it is sparse.
            if patch_list.len() <= eps0.len() / 20 {
                let patched = EditsBlock::Quantized {
                    spat: spat_q,
                    freq: freq_q.clone(),
                    patch: patch_list,
                };
                if edits_satisfy_bounds(&eps0, &patched, shape, &bounds, cfg.threads, scratch) {
                    stats.iterations = result.iterations;
                    stats.converged = true;
                    chosen = Some((patched, result));
                    break;
                }
            }
        }
    }

    let (block, result) = match chosen {
        Some(x) => x,
        None => {
            // Raw fallback: store exact f64 edits; dual bounds then hold by
            // the projector's construction.
            let params = PocsParams {
                spatial: bounds.spatial.clone(),
                frequency: bounds.frequency.clone(),
                max_iters: cfg.max_iters,
                threads: cfg.threads,
            };
            let result = alternating_projection_with_scratch(&eps0, shape, &params, scratch);
            if !result.converged {
                bail!("POCS did not converge even without quantization shrink");
            }
            let spat: Vec<(u32, f64)> = result
                .spat_edits
                .iter()
                .enumerate()
                .filter(|(_, &v)| v != 0.0)
                .map(|(i, &v)| (i as u32, v))
                .collect();
            let freq: Vec<(u32, f64, f64)> = result
                .freq_edits
                .expand()
                .iter()
                .enumerate()
                .filter(|(_, c)| c.re != 0.0 || c.im != 0.0)
                .map(|(i, c)| (i as u32, c.re, c.im))
                .collect();
            stats.used_raw_fallback = true;
            stats.iterations = result.iterations;
            stats.converged = true;
            (
                EditsBlock::Raw {
                    n: eps0.len(),
                    spat,
                    freq,
                },
                result,
            )
        }
    };
    stats.active_spat = result.active_spat;
    stats.active_freq = result.active_freq;

    let metrics = retry_metrics();
    metrics.attempts.add(stats.quant_attempts as u64);
    if stats.used_raw_fallback {
        metrics.raw_fallbacks.incr();
    }

    Ok(FfczArchive {
        base_name: base_name.to_string(),
        base_payload,
        edits: block,
        stats,
    })
}

/// Registry handles for the quantization retry ladder, fetched once:
/// `correction.retry.attempts` (total ladder attempts across all encodes)
/// and `correction.retry.raw_fallbacks` (chunks that abandoned
/// quantization for raw f64 edits).
struct RetryMetrics {
    attempts: crate::telemetry::Counter,
    raw_fallbacks: crate::telemetry::Counter,
}

fn retry_metrics() -> &'static RetryMetrics {
    static METRICS: std::sync::OnceLock<RetryMetrics> = std::sync::OnceLock::new();
    METRICS.get_or_init(|| RetryMetrics {
        attempts: crate::telemetry::counter("correction.retry.attempts"),
        raw_fallbacks: crate::telemetry::counter("correction.retry.raw_fallbacks"),
    })
}

/// Check the dual bounds for `eps0 + edits` (dequantized): the retry
/// ladder's per-attempt verifier. Equivalent to
/// [`apply::corrected_eps`] followed by [`check_dual_bounds`] (same
/// arithmetic — IEEE addition is commutative — and the same `1 + 1e-9`
/// verifier roundoff tolerance), but fused through `scratch` so the
/// corrected-ε candidate, the Hermitian fold target, and the verification
/// spectrum all live in warmed grow-only buffers: after the first attempt
/// on a shape, a re-check performs zero scratch allocations. `threads`
/// drives the transforms (bit-identical for every count).
fn edits_satisfy_bounds(
    eps0: &[f64],
    block: &EditsBlock,
    shape: &[usize],
    bounds: &ResolvedBounds,
    threads: usize,
    scratch: &mut CorrectionScratch,
) -> bool {
    let n = eps0.len();
    let threads = threads.max(1);
    let plan = scratch.plan(shape);
    let h = plan.half_len();
    scratch.ensure_spec(h);
    scratch.ensure_spec2(h);
    scratch.ensure_real(n);
    let CorrectionScratch {
        spec, spec2, real, ws, ..
    } = scratch;
    let spec = &mut spec[..h];
    let spec2 = &mut spec2[..h];
    let eps = &mut real[..n];
    // ε = ε₀ + spat + Re(IFFT(freq)), built in place — sparse-aware: the
    // Hermitian fold of the frequency edits is scattered from the stored
    // sparse streams straight into the scratch half spectrum, and the
    // spatial edits merge in from their ascending index list, so the
    // verifier allocates no dense edit vectors (previously
    // `EditsBlock::dense()` built two O(n) vectors per attempt — the last
    // per-check allocations on the encode retry ladder). Bit-identical to
    // the dense path; see `scatter_freq_folded` / `add_eps0_and_spat`.
    block.scatter_freq_folded(shape, spec2);
    plan.inverse(spec2, eps, threads, ws);
    block.add_eps0_and_spat(eps0, eps);
    // Ratios and tolerance shared with `check_dual_bounds`.
    let max_s = pocs::max_spatial_ratio(eps, &bounds.spatial);
    plan.forward(eps, spec, threads, ws);
    let max_f = pocs::max_frequency_ratio_half(spec, shape, &bounds.frequency);
    max_s <= pocs::VERIFIER_TOL && max_f <= pocs::VERIFIER_TOL
}

/// Decompress an FFCz archive: base decompress + edit application. The
/// base compressor is resolved through the codec registry
/// ([`crate::codec::build_compressor`]), so archives referencing
/// runtime-registered compressors decode as long as the codec was
/// registered in this process.
pub fn decompress(archive: &FfczArchive) -> Result<Field> {
    decompress_with_scratch(archive, &mut CorrectionScratch::new())
}

/// [`decompress`] with caller-owned transform state: batch decoders (the
/// store read path, the archive read server) reuse one scratch so the
/// inverse-transform plans and buffers warm once per chunk shape.
pub fn decompress_with_scratch(
    archive: &FfczArchive,
    scratch: &mut CorrectionScratch,
) -> Result<Field> {
    let base = crate::codec::require_compressor(&archive.base_name)?;
    let recon0 = base.decompress(&archive.base_payload)?;
    apply::apply_edits_with_scratch(&recon0, &archive.edits, scratch)
}

/// Outcome of [`verify`].
#[derive(Debug, Clone)]
pub struct VerifyReport {
    pub spatial_ok: bool,
    pub frequency_ok: bool,
    /// max |ε_n| / E_n over samples (≤ 1 is in-bound).
    pub max_spatial_ratio: f64,
    /// max ‖δ_k‖∞ / Δ_k over components (≤ 1 is in-bound).
    pub max_frequency_ratio: f64,
}

/// Verify that a reconstruction satisfies the configured dual bounds
/// against the original field.
pub fn verify(original: &Field, reconstruction: &Field, cfg: &FfczConfig) -> VerifyReport {
    verify_with_scratch(original, reconstruction, cfg, &mut CorrectionScratch::new())
}

/// [`verify`] with caller-owned transform state — the store encoder
/// verifies every chunk it writes, so the per-worker scratch serves this
/// transform too.
pub fn verify_with_scratch(
    original: &Field,
    reconstruction: &Field,
    cfg: &FfczConfig,
    scratch: &mut CorrectionScratch,
) -> VerifyReport {
    let bounds = resolve_bounds_with_scratch(original, cfg, scratch);
    let eps: Vec<f64> = reconstruction
        .data()
        .iter()
        .zip(original.data())
        .map(|(r, x)| r - x)
        .collect();
    let (spatial_ok, frequency_ok, max_s, max_f) = check_dual_bounds_with_scratch(
        &eps,
        original.shape(),
        &bounds.spatial,
        &bounds.frequency,
        cfg.threads,
        scratch,
    );
    VerifyReport {
        spatial_ok,
        frequency_ok,
        max_spatial_ratio: max_s,
        max_frequency_ratio: max_f,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::szlike::SzLike;
    use crate::data::synth;

    #[test]
    fn end_to_end_dual_bounds_hold() {
        let field = synth::grf::GrfBuilder::new(&[16, 16, 16])
            .lognormal(1.0)
            .seed(21)
            .build();
        let base = SzLike::default();
        let cfg = FfczConfig::relative(1e-3, 1e-3);
        let archive = compress(&field, &base, &cfg).unwrap();
        let recon = decompress(&archive).unwrap();
        let report = verify(&field, &recon, &cfg);
        assert!(
            report.spatial_ok && report.frequency_ok,
            "report: {report:?}"
        );
    }

    #[test]
    fn archive_roundtrips_bytes() {
        let field = synth::eeg::EegBuilder::new(2048).seed(4).build();
        let base = SzLike::default();
        let cfg = FfczConfig::relative(1e-3, 5e-4);
        let archive = compress(&field, &base, &cfg).unwrap();
        let bytes = archive.to_bytes();
        let back = FfczArchive::from_bytes(&bytes).unwrap();
        assert_eq!(archive.base_name, back.base_name);
        assert_eq!(archive.base_payload, back.base_payload);
        assert_eq!(archive.edits, back.edits);
        let r1 = decompress(&archive).unwrap();
        let r2 = decompress(&back).unwrap();
        assert_eq!(r1.data(), r2.data());
    }

    #[test]
    fn frequency_accuracy_improves_over_base() {
        let field = synth::grf::GrfBuilder::new(&[32, 32])
            .lognormal(1.2)
            .seed(5)
            .build();
        let base = SzLike::default();
        let cfg = FfczConfig::relative(1e-2, 1e-4);
        // Base alone.
        let payload = base
            .compress(&field, crate::compressors::ErrorBound::Relative(1e-2))
            .unwrap();
        let recon_base = base.decompress(&payload).unwrap();
        // With FFCz.
        let archive = compress(&field, &base, &cfg).unwrap();
        let recon_ffcz = decompress(&archive).unwrap();
        let (_, rfe_base) = crate::metrics::spectral_metrics(&field, &recon_base);
        let (_, rfe_ffcz) = crate::metrics::spectral_metrics(&field, &recon_ffcz);
        assert!(
            rfe_ffcz < rfe_base,
            "RFE should improve: base {rfe_base}, ffcz {rfe_ffcz}"
        );
        let report = verify(&field, &recon_ffcz, &cfg);
        assert!(report.spatial_ok && report.frequency_ok);
    }

    #[test]
    fn power_spectrum_mode_bounds_each_bin() {
        let field = synth::grf::GrfBuilder::new(&[32, 32])
            .lognormal(1.0)
            .seed(6)
            .build();
        let base = SzLike::default();
        let cfg = FfczConfig::power_spectrum(1e-2, 1e-3);
        let archive = compress(&field, &base, &cfg).unwrap();
        let recon = decompress(&archive).unwrap();
        let ps0 = crate::fourier::power_spectrum(&field);
        let ps1 = crate::fourier::power_spectrum(&recon);
        let max_rel = ps1.max_relative_error(&ps0);
        assert!(max_rel <= 1.1e-3, "power-spectrum rel err {max_rel}");
    }

    #[test]
    fn sparse_fold_scatter_matches_dense_reference() {
        use crate::fourier::{fold_full_into, half_len, rfftn};
        use crate::util::XorShift;

        // Build edit blocks of every variant from genuinely Hermitian
        // spectra (the only kind the encoder produces) and pin the sparse
        // scatter / merge-walk paths *bitwise* against the dense
        // `EditsBlock::dense()` reference they replaced.
        let shapes: [&[usize]; 4] = [&[16], &[9], &[6, 8], &[3, 4, 5]];
        for (si, shape) in shapes.iter().enumerate() {
            let n: usize = shape.iter().product();
            let h = half_len(shape);
            let mut rng = XorShift::new(90 + si as u64);
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let spec_half = rfftn(&x, shape);
            let spat_dense: Vec<f64> = (0..n)
                .map(|_| if rng.next_f64() < 0.3 { rng.normal() * 1e-3 } else { 0.0 })
                .collect();
            let spat_q = QuantizedEdits::quantize(&spat_dense);
            // Patch entries exactly as the retry ladder builds them: a
            // full-bin walk over a Hermitian spectrum with a
            // mirror-symmetric (magnitude) selection — entries land in
            // exact conjugate pairs.
            let t = spec_half
                .data()
                .iter()
                .map(|c| c.linf())
                .sum::<f64>()
                / (h as f64);
            let mut patch: Vec<(u32, f64, f64)> = Vec::new();
            for_each_full_bin(shape, |full, half, conj| {
                let stored = spec_half.data()[half];
                let d = if conj { stored.conj() } else { stored };
                if d.linf() > t {
                    patch.push((full as u32, d.re * 1e-4, d.im * 1e-4));
                }
            });
            assert!(!patch.is_empty(), "shape {shape:?}: degenerate patch");
            let raw_spat: Vec<(u32, f64)> = spat_dense
                .iter()
                .enumerate()
                .filter(|(_, &v)| v != 0.0)
                .map(|(i, &v)| (i as u32, v))
                .collect();
            let raw_freq: Vec<(u32, f64, f64)> = spec_half
                .expand()
                .iter()
                .enumerate()
                .filter(|(_, c)| c.re != 0.0 || c.im != 0.0)
                .map(|(i, c)| (i as u32, c.re, c.im))
                .collect();
            let blocks = vec![
                EditsBlock::Quantized {
                    spat: spat_q.clone(),
                    freq: QuantizedComplexEdits::quantize_half(&spec_half),
                    patch: Vec::new(),
                },
                EditsBlock::Quantized {
                    spat: spat_q.clone(),
                    freq: QuantizedComplexEdits::quantize_half(&spec_half),
                    patch,
                },
                EditsBlock::PointwiseQuantized {
                    spat: spat_q.clone(),
                    freq: PointwiseQuantizedEdits::quantize_half(&spec_half, |_| 1.0, 0.25),
                },
                EditsBlock::Raw {
                    n,
                    spat: raw_spat,
                    freq: raw_freq,
                },
            ];
            for (bi, block) in blocks.iter().enumerate() {
                let (spat_d, freq_d) = block.dense();
                let mut ref_fold = vec![Complex::ZERO; h];
                fold_full_into(&freq_d, shape, &mut ref_fold);
                // Pre-fill with junk: the scatter owns the whole buffer.
                let mut got_fold = vec![Complex::new(7.0, -7.0); h];
                block.scatter_freq_folded(shape, &mut got_fold);
                for i in 0..h {
                    assert_eq!(
                        (got_fold[i].re.to_bits(), got_fold[i].im.to_bits()),
                        (ref_fold[i].re.to_bits(), ref_fold[i].im.to_bits()),
                        "shape {shape:?} block {bi} bin {i}: \
                         sparse {:?} vs dense {:?}",
                        got_fold[i],
                        ref_fold[i]
                    );
                }
                let eps0: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                let base: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                let mut ref_eps = base.clone();
                for i in 0..n {
                    ref_eps[i] += eps0[i] + spat_d[i];
                }
                let mut got_eps = base.clone();
                block.add_eps0_and_spat(&eps0, &mut got_eps);
                for i in 0..n {
                    assert_eq!(
                        got_eps[i].to_bits(),
                        ref_eps[i].to_bits(),
                        "shape {shape:?} block {bi} sample {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn sparse_verifier_is_allocation_free_when_warm() {
        use crate::util::XorShift;

        // The retry-ladder verifier must perform zero scratch allocations
        // once warm on a shape — with `EditsBlock::dense()` gone, the
        // whole per-attempt check runs in grow-only buffers.
        let shape = [12usize, 10];
        let n = 120usize;
        let mut rng = XorShift::new(31);
        let eps0: Vec<f64> = (0..n).map(|_| rng.normal() * 1e-3).collect();
        let spat: Vec<f64> = (0..n).map(|_| rng.normal() * 1e-4).collect();
        let freq = crate::fourier::rfftn(&eps0, &shape);
        let block = EditsBlock::Quantized {
            spat: QuantizedEdits::quantize(&spat),
            freq: QuantizedComplexEdits::quantize_half(&freq),
            patch: Vec::new(),
        };
        let bounds = ResolvedBounds {
            spatial: Bounds::Global(1.0),
            frequency: Bounds::Global(1e3),
            spectral_rule: None,
        };
        let mut scratch = CorrectionScratch::new();
        let verdict_cold = edits_satisfy_bounds(&eps0, &block, &shape, &bounds, 1, &mut scratch);
        let warm = scratch.allocation_events();
        for _ in 0..3 {
            let verdict = edits_satisfy_bounds(&eps0, &block, &shape, &bounds, 1, &mut scratch);
            assert_eq!(verdict, verdict_cold, "verdict changed across reuse");
        }
        assert_eq!(
            scratch.allocation_events(),
            warm,
            "warm verifier allocated scratch"
        );
    }

    #[test]
    fn stats_are_recorded() {
        let field = synth::turbulence::TurbulenceBuilder::new(&[16, 16, 16])
            .seed(7)
            .build();
        let cfg = FfczConfig::relative(1e-3, 1e-3);
        let archive = compress(&field, &SzLike::default(), &cfg).unwrap();
        assert!(archive.stats.converged);
        assert!(archive.stats.iterations >= 1);
    }
}
