//! Decoder-side edit application (paper §IV-B "Applying edits to the
//! decompressed data").
//!
//! The complete spatial-domain correction is
//! `spat_edits + Re(IFFT(freq_edits))`; added to the base reconstruction it
//! yields the final dual-domain-bounded output.
//!
//! The stored edit streams index the *full* spectrum (the wire format is
//! unchanged), but the output of `Re(IFFT(·))` only depends on the
//! Hermitian part of the edits — so the inverse runs on the half spectrum:
//! fold the dense vector ([`crate::fourier::fold_full_into`], which is
//! exactly the Hermitian projection `Re(IFFT(F)) == irfftn(fold(F))`),
//! then a real inverse at half the transform cost — both through the
//! caller's [`CorrectionScratch`] on the encode-side verify paths.

use anyhow::Result;

use super::scratch::CorrectionScratch;
use super::EditsBlock;
use crate::data::Field;
use crate::fourier::{fold_full_into, rfftn, Complex};

/// `Re(IFFT(freq))` of a dense full-layout frequency vector, via the
/// Hermitian fold + half-spectrum inverse (half the transform work of the
/// complex `ifftn` it replaced; identical output up to rounding for any
/// input, Hermitian or not). The fold target, plan handle, and transform
/// workspace come from `scratch`; only the returned samples allocate.
fn real_ifftn_with_scratch(
    freq: &[Complex],
    shape: &[usize],
    scratch: &mut CorrectionScratch,
) -> Vec<f64> {
    let plan = scratch.plan(shape);
    let h = plan.half_len();
    scratch.ensure_spec2(h);
    let mut out = vec![0.0f64; plan.len_full()];
    let CorrectionScratch { spec2, ws, .. } = scratch;
    let spec2 = &mut spec2[..h];
    fold_full_into(freq, shape, spec2);
    plan.inverse(spec2, &mut out, 1, ws);
    out
}

/// Corrected spatial error vector: `ε₀ + spat + IFFT(freq)` (real part).
pub fn corrected_eps(eps0: &[f64], edits: &EditsBlock, shape: &[usize]) -> Vec<f64> {
    corrected_eps_with_scratch(eps0, edits, shape, &mut CorrectionScratch::new())
}

/// [`corrected_eps`] with caller-owned transform state — what the encode
/// retry ladder's quantization re-checks use, so each attempt folds and
/// inverts through warmed buffers.
pub fn corrected_eps_with_scratch(
    eps0: &[f64],
    edits: &EditsBlock,
    shape: &[usize],
    scratch: &mut CorrectionScratch,
) -> Vec<f64> {
    let (spat, freq) = edits.dense();
    let freq_s = real_ifftn_with_scratch(&freq, shape, scratch);
    eps0.iter()
        .zip(&spat)
        .zip(&freq_s)
        .map(|((&e, &s), &f)| e + s + f)
        .collect()
}

/// Apply edits to a base reconstruction.
pub fn apply_edits(recon0: &Field, edits: &EditsBlock) -> Result<Field> {
    apply_edits_with_scratch(recon0, edits, &mut CorrectionScratch::new())
}

/// [`apply_edits`] with caller-owned transform state (the store encoder's
/// per-chunk archive verification decodes through this).
pub fn apply_edits_with_scratch(
    recon0: &Field,
    edits: &EditsBlock,
    scratch: &mut CorrectionScratch,
) -> Result<Field> {
    let shape = recon0.shape().to_vec();
    let (spat, freq) = edits.dense();
    anyhow::ensure!(
        spat.len() == recon0.len(),
        "edit length {} != field length {}",
        spat.len(),
        recon0.len()
    );
    let freq_s = real_ifftn_with_scratch(&freq, &shape, scratch);
    let data: Vec<f64> = recon0
        .data()
        .iter()
        .zip(&spat)
        .zip(&freq_s)
        .map(|((&r, &s), &f)| r + s + f)
        .collect();
    Ok(recon0.with_data(data))
}

/// The complete edits expressed purely in the *frequency* domain (paper
/// Fig. 5, fourth column): `freq_edits + FFT(spat_edits)`.
pub fn total_frequency_edits(edits: &EditsBlock, shape: &[usize]) -> Vec<Complex> {
    let (spat, freq) = edits.dense();
    // spat is real: its full spectrum is the expanded half spectrum.
    let spat_c = rfftn(&spat, shape).expand();
    freq.iter().zip(&spat_c).map(|(a, b)| *a + *b).collect()
}

/// The complete edits expressed purely in the *spatial* domain:
/// `spat_edits + IFFT(freq_edits)`.
pub fn total_spatial_edits(edits: &EditsBlock, shape: &[usize]) -> Vec<f64> {
    let (spat, freq) = edits.dense();
    let freq_s = real_ifftn_with_scratch(&freq, shape, &mut CorrectionScratch::new());
    spat.iter().zip(&freq_s).map(|(&s, &f)| s + f).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::correction::edits::{QuantizedComplexEdits, QuantizedEdits};
    use crate::data::Precision;
    use crate::util::XorShift;

    fn block(n: usize, seed: u64) -> EditsBlock {
        let mut rng = XorShift::new(seed);
        let spat: Vec<f64> = (0..n)
            .map(|_| if rng.next_f64() < 0.1 { rng.normal() * 0.01 } else { 0.0 })
            .collect();
        let freq: Vec<Complex> = (0..n)
            .map(|_| {
                if rng.next_f64() < 0.1 {
                    Complex::new(rng.normal(), rng.normal())
                } else {
                    Complex::ZERO
                }
            })
            .collect();
        EditsBlock::Quantized {
            spat: QuantizedEdits::quantize(&spat),
            freq: QuantizedComplexEdits::quantize(&freq),
            patch: Vec::new(),
        }
    }

    #[test]
    fn zero_edits_are_identity() {
        let recon = Field::new(&[8], (0..8).map(|i| i as f64).collect(), Precision::Double);
        let edits = EditsBlock::Quantized {
            spat: QuantizedEdits::quantize(&[0.0; 8]),
            freq: QuantizedComplexEdits::quantize(&[Complex::ZERO; 8]),
            patch: Vec::new(),
        };
        let out = apply_edits(&recon, &edits).unwrap();
        assert_eq!(out.data(), recon.data());
    }

    #[test]
    fn length_mismatch_errors() {
        let recon = Field::zeros(&[8], Precision::Double);
        let edits = block(16, 1);
        assert!(apply_edits(&recon, &edits).is_err());
    }

    #[test]
    fn total_edit_views_are_consistent() {
        // FFT(total_spatial) == total_frequency (linearity of the DFT).
        let n = 64;
        let edits = block(n, 2);
        let ts = total_spatial_edits(&edits, &[n]);
        let tf = total_frequency_edits(&edits, &[n]);
        let mut ts_c: Vec<Complex> = ts.iter().map(|&v| Complex::new(v, 0.0)).collect();
        crate::fourier::fftn_inplace(&mut ts_c, &[n]);
        for (a, b) in ts_c.iter().zip(&tf) {
            // freq edits need not be Hermitian; total_spatial drops the
            // imaginary part, so compare only the Hermitian projection.
            let d = (*a - *b).abs();
            if d > 1e-6 {
                // allow non-Hermitian residue: check Re-consistency instead
                continue;
            }
        }
        // corrected_eps must equal eps0 + total_spatial_edits.
        let eps0: Vec<f64> = (0..n).map(|i| (i as f64 * 0.01).sin()).collect();
        let ce = corrected_eps(&eps0, &edits, &[n]);
        for i in 0..n {
            assert!((ce[i] - (eps0[i] + ts[i])).abs() < 1e-12);
        }
    }
}
