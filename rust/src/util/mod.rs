//! Small shared utilities: seeded PRNG, statistics, timers, formatting.

pub mod bench;
pub mod json;
pub mod rng;
pub mod stats;
pub mod sync;

pub use rng::XorShift;
pub use stats::Summary;

/// Format a byte count as a human-readable string (`1.50 MiB`).
pub fn human_bytes(n: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", n, UNITS[0])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Format a duration in adaptive units (`12.3 ms`, `1.20 s`).
pub fn human_duration(d: std::time::Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{:.2} s", s)
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.2} µs", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(12), "12 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn human_duration_units() {
        assert_eq!(
            human_duration(std::time::Duration::from_millis(1500)),
            "1.50 s"
        );
        assert_eq!(
            human_duration(std::time::Duration::from_micros(1500)),
            "1.50 ms"
        );
    }
}
