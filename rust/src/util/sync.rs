//! Poison-recovering lock acquisition.
//!
//! The crate's shared state behind `Mutex`/`RwLock` (decoded-chunk LRU
//! caches, codec registries, plan caches) is kept consistent by the
//! holders themselves — every critical section either completes its
//! bookkeeping or mutates nothing observable. A panic on one thread
//! (say, a codec assertion in a worker) must therefore not poison the
//! lock for every *other* thread: a concurrent read service would turn
//! one bad chunk into a process-wide denial. These helpers take the
//! guard out of a poisoned lock and carry on, which is the crate-wide
//! policy for library paths (`.unwrap()` on locks is banned there by
//! the `panic-policy` lint).

use std::sync::{Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// `Mutex::lock` that recovers the guard from a poisoned lock.
pub fn lock<T>(mutex: &Mutex<T>) -> MutexGuard<'_, T> {
    mutex.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// `RwLock::read` that recovers the guard from a poisoned lock.
pub fn read<T>(rwlock: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    rwlock.read().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// `RwLock::write` that recovers the guard from a poisoned lock.
pub fn write<T>(rwlock: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    rwlock.write().unwrap_or_else(|poisoned| poisoned.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex, RwLock};

    #[test]
    fn mutex_survives_a_panicking_holder() {
        let m = Arc::new(Mutex::new(7));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _guard = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert_eq!(*lock(&m), 7);
        *lock(&m) = 8;
        assert_eq!(*lock(&m), 8);
    }

    #[test]
    fn rwlock_survives_a_panicking_writer() {
        let l = Arc::new(RwLock::new(1));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _guard = l2.write().unwrap();
            panic!("poison it");
        })
        .join();
        assert_eq!(*read(&l), 1);
        *write(&l) = 2;
        assert_eq!(*read(&l), 2);
    }
}
