//! Minimal benchmarking harness.
//!
//! `criterion` is not available in the offline crate set, so `cargo bench`
//! targets (declared with `harness = false`) use this module: warmup,
//! repeated timing, and median/mean/σ reporting, plus derived throughput.

use std::time::{Duration, Instant};

use super::stats::{median, Summary};

/// Result of a benchmark run.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub stddev: Duration,
    pub min: Duration,
    /// Optional number of bytes processed per iteration (for GB/s).
    pub bytes_per_iter: Option<usize>,
    /// Optional number of "elements" processed per iteration.
    pub elems_per_iter: Option<usize>,
}

impl BenchResult {
    /// Throughput in GB/s if `bytes_per_iter` was provided.
    pub fn gbps(&self) -> Option<f64> {
        self.bytes_per_iter
            .map(|b| b as f64 / self.median.as_secs_f64() / 1e9)
    }

    /// Elements per second if `elems_per_iter` was provided.
    pub fn eps(&self) -> Option<f64> {
        self.elems_per_iter
            .map(|e| e as f64 / self.median.as_secs_f64())
    }

    /// One-line report string.
    pub fn report(&self) -> String {
        let mut s = format!(
            "{:<40} {:>12} median  {:>12} mean  ±{:>10}",
            self.name,
            crate::util::human_duration(self.median),
            crate::util::human_duration(self.mean),
            crate::util::human_duration(self.stddev),
        );
        if let Some(g) = self.gbps() {
            s.push_str(&format!("  {g:8.3} GB/s"));
        }
        if let Some(e) = self.eps() {
            s.push_str(&format!("  {:.3e} elem/s", e));
        }
        s
    }
}

/// Benchmark builder.
pub struct Bench {
    name: String,
    warmup: usize,
    samples: usize,
    bytes_per_iter: Option<usize>,
    elems_per_iter: Option<usize>,
    min_time: Duration,
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            warmup: 2,
            samples: 10,
            bytes_per_iter: None,
            elems_per_iter: None,
            min_time: Duration::from_millis(50),
        }
    }

    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup = n;
        self
    }

    pub fn samples(mut self, n: usize) -> Self {
        self.samples = n.max(1);
        self
    }

    pub fn bytes(mut self, b: usize) -> Self {
        self.bytes_per_iter = Some(b);
        self
    }

    pub fn elems(mut self, e: usize) -> Self {
        self.elems_per_iter = Some(e);
        self
    }

    /// Run `f` repeatedly and collect timing statistics. `f` should perform
    /// one complete unit of work per call and return something observable so
    /// the optimizer can't delete it (use [`black_box`]).
    pub fn run<T>(self, mut f: impl FnMut() -> T) -> BenchResult {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            // Batch very fast functions until min_time is exceeded so the
            // timer resolution doesn't dominate.
            let mut batch = 1usize;
            loop {
                let t0 = Instant::now();
                for _ in 0..batch {
                    black_box(f());
                }
                let dt = t0.elapsed();
                if dt >= self.min_time || batch >= 1 << 20 {
                    times.push(dt.as_secs_f64() / batch as f64);
                    break;
                }
                batch *= 4;
            }
        }
        let s = Summary::from_slice(&times);
        BenchResult {
            name: self.name,
            iters: self.samples,
            median: Duration::from_secs_f64(median(&times)),
            mean: Duration::from_secs_f64(s.mean()),
            stddev: Duration::from_secs_f64(s.stddev()),
            min: Duration::from_secs_f64(s.min()),
            bytes_per_iter: self.bytes_per_iter,
            elems_per_iter: self.elems_per_iter,
        }
    }
}

/// Opaque value sink, preventing dead-code elimination of benchmark bodies.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_times_something() {
        // black_box keeps release mode from constant-folding the body.
        let r = Bench::new("spin")
            .warmup(1)
            .samples(3)
            .run(|| (0..black_box(1000u64)).sum::<u64>());
        assert!(r.median.as_nanos() > 0, "median {:?}", r.median);
        assert_eq!(r.iters, 3);
    }

    #[test]
    fn throughput_derivation() {
        let r = Bench::new("bytes")
            .warmup(0)
            .samples(2)
            .bytes(1_000_000)
            .run(|| std::thread::sleep(Duration::from_millis(1)));
        let g = r.gbps().unwrap();
        assert!(g > 0.0 && g < 10.0, "gbps {g}");
    }
}
