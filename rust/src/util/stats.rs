//! Streaming summary statistics used by metrics and the bench harness.

/// Online summary of a sequence of f64 samples (Welford's algorithm).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: usize,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Incorporate one sample.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn from_slice(xs: &[f64]) -> Self {
        let mut s = Self::new();
        for &x in xs {
            s.push(x);
        }
        s
    }

    pub fn count(&self) -> usize {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator); 0 for n < 2.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Median of a slice (copies + sorts; fine for bench-sized inputs).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mid = v.len() / 2;
    if v.len() % 2 == 0 {
        (v[mid - 1] + v[mid]) / 2.0
    } else {
        v[mid]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_matches_manual() {
        let s = Summary::from_slice(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.variance() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn median_even_odd() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 3.0, 2.0]), 2.5);
        assert!(median(&[]).is_nan());
    }
}
