//! Minimal hand-rolled JSON support.
//!
//! The crate is offline (no serde); the few places that *emit* JSON
//! (benches, [`crate::telemetry`]) hand-roll strings. This module adds the
//! other direction — a small recursive-descent parser producing a
//! [`Json`] value tree — so tests can round-trip
//! [`crate::telemetry::Snapshot`] output and validate `--trace-out` files
//! without external dependencies. It parses the JSON this crate writes
//! (objects, arrays, strings with `\uXXXX` escapes, f64 numbers, bools,
//! null); it is not meant as a general standards-lab validator.

use anyhow::{bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Key/value pairs in source order (duplicate keys are kept as-is).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let value = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            bail!("trailing garbage at byte {pos}");
        }
        Ok(value)
    }

    /// Object field lookup (first match); `None` on non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// Numeric value as `u64` (must be a non-negative integer ≤ 2^53).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 && *v <= 9_007_199_254_740_992.0 => {
                Some(*v as u64)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(fields) => Some(fields),
            _ => None,
        }
    }
}

/// Escape a string for embedding in a JSON document (adds no quotes).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn skip_ws(bytes: &[u8], pos: &mut usize) {
    while *pos < bytes.len() && matches!(bytes[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(bytes: &[u8], pos: &mut usize, byte: u8) -> Result<()> {
    if *pos < bytes.len() && bytes[*pos] == byte {
        *pos += 1;
        Ok(())
    } else {
        bail!("expected '{}' at byte {}", byte as char, *pos);
    }
}

fn parse_value(bytes: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(bytes, pos);
    match bytes.get(*pos) {
        Some(b'{') => parse_obj(bytes, pos),
        Some(b'[') => parse_arr(bytes, pos),
        Some(b'"') => Ok(Json::Str(parse_string(bytes, pos)?)),
        Some(b't') => parse_lit(bytes, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(bytes, pos, "false", Json::Bool(false)),
        Some(b'n') => parse_lit(bytes, pos, "null", Json::Null),
        Some(_) => parse_num(bytes, pos),
        None => bail!("unexpected end of input"),
    }
}

fn parse_lit(bytes: &[u8], pos: &mut usize, lit: &str, value: Json) -> Result<Json> {
    if bytes[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(value)
    } else {
        bail!("invalid literal at byte {}", *pos);
    }
}

fn parse_num(bytes: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < bytes.len()
        && matches!(bytes[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&bytes[start..*pos]).expect("ascii slice");
    match text.parse::<f64>() {
        Ok(v) => Ok(Json::Num(v)),
        Err(_) => bail!("invalid number {text:?} at byte {start}"),
    }
}

fn parse_string(bytes: &[u8], pos: &mut usize) -> Result<String> {
    expect(bytes, pos, b'"')?;
    let mut out = String::new();
    loop {
        let Some(&b) = bytes.get(*pos) else {
            bail!("unterminated string");
        };
        *pos += 1;
        match b {
            b'"' => return Ok(out),
            b'\\' => {
                let Some(&esc) = bytes.get(*pos) else {
                    bail!("unterminated escape");
                };
                *pos += 1;
                match esc {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 > bytes.len() {
                            bail!("truncated \\u escape");
                        }
                        let hex = std::str::from_utf8(&bytes[*pos..*pos + 4])
                            .ok()
                            .and_then(|h| u32::from_str_radix(h, 16).ok());
                        let Some(code) = hex else {
                            bail!("invalid \\u escape at byte {}", *pos);
                        };
                        *pos += 4;
                        // Surrogate pairs are not emitted by this crate's
                        // writers; map lone surrogates to U+FFFD.
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    other => bail!("invalid escape '\\{}'", other as char),
                }
            }
            _ => {
                // Re-sync to char boundary for multi-byte UTF-8.
                let rest = std::str::from_utf8(&bytes[*pos - 1..])
                    .map_err(|_| anyhow::anyhow!("invalid UTF-8 in string"))?;
                let c = rest.chars().next().expect("non-empty");
                out.push(c);
                *pos += c.len_utf8() - 1;
            }
        }
    }
}

fn parse_arr(bytes: &[u8], pos: &mut usize) -> Result<Json> {
    expect(bytes, pos, b'[')?;
    let mut items = Vec::new();
    skip_ws(bytes, pos);
    if bytes.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(bytes, pos)?);
        skip_ws(bytes, pos);
        match bytes.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => bail!("expected ',' or ']' at byte {}", *pos),
        }
    }
}

fn parse_obj(bytes: &[u8], pos: &mut usize) -> Result<Json> {
    expect(bytes, pos, b'{')?;
    let mut fields = Vec::new();
    skip_ws(bytes, pos);
    if bytes.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(fields));
    }
    loop {
        skip_ws(bytes, pos);
        let key = parse_string(bytes, pos)?;
        skip_ws(bytes, pos);
        expect(bytes, pos, b':')?;
        let value = parse_value(bytes, pos)?;
        fields.push((key, value));
        skip_ws(bytes, pos);
        match bytes.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            _ => bail!("expected ',' or '}}' at byte {}", *pos),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_nested_document() {
        let doc = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": true}, "e": null}"#;
        let v = Json::parse(doc).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[0].as_u64(), Some(1));
        assert_eq!(a[1].as_f64(), Some(2.5));
        assert_eq!(a[2].as_f64(), Some(-300.0));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("x\ny"));
        assert_eq!(v.get("b").unwrap().get("d"), Some(&Json::Bool(true)));
        assert_eq!(v.get("e"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn escape_round_trips_through_parse() {
        let raw = "a\"b\\c\nd\te\u{1}f µ";
        let doc = format!("{{\"k\": \"{}\"}}", escape(raw));
        let v = Json::parse(&doc).unwrap();
        assert_eq!(v.get("k").unwrap().as_str(), Some(raw));
    }

    #[test]
    fn u64_guard_rejects_fractions_and_negatives() {
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
        assert_eq!(Json::parse("-2").unwrap().as_u64(), None);
        assert_eq!(Json::parse("42").unwrap().as_u64(), Some(42));
    }
}
