//! Seeded xorshift PRNG.
//!
//! The offline crate set does not include `rand`, so every stochastic
//! component in this crate (synthetic data generators, property tests,
//! workload generators) draws from this deterministic generator. It is
//! xorshift64* — statistically solid for simulation purposes and exactly
//! reproducible across platforms.

/// A deterministic 64-bit xorshift* generator.
#[derive(Debug, Clone)]
pub struct XorShift {
    state: u64,
}

impl XorShift {
    /// Create a generator from a seed. A zero seed is mapped to a fixed
    /// non-zero constant (xorshift has a zero fixed point).
    pub fn new(seed: u64) -> Self {
        Self {
            state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)`. Uses rejection-free multiply-shift; the
    /// tiny modulo bias is irrelevant for simulation workloads.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal sample via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        // Avoid log(0).
        let u1 = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// A pair of independent standard normal samples (both Box–Muller
    /// outputs, saving one log/sqrt per pair).
    pub fn normal_pair(&mut self) -> (f64, f64) {
        let u1 = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let t = 2.0 * std::f64::consts::PI * u2;
        (r * t.cos(), r * t.sin())
    }

    /// Fork a child generator with a decorrelated stream (splitmix of the
    /// current state and a stream id).
    pub fn fork(&mut self, stream: u64) -> XorShift {
        let mut z = self
            .next_u64()
            .wrapping_add(stream.wrapping_mul(0xBF58476D1CE4E5B9));
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        XorShift::new(z ^ (z >> 31))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = XorShift::new(42);
        let mut b = XorShift::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = XorShift::new(1);
        let mut b = XorShift::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = XorShift::new(7);
        for _ in 0..1000 {
            let v = r.uniform(-3.0, 5.0);
            assert!((-3.0..5.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = XorShift::new(9);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sum2 = 0.0;
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn zero_seed_is_usable() {
        let mut r = XorShift::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn fork_decorrelates() {
        let mut r = XorShift::new(5);
        let mut c1 = r.fork(0);
        let mut c2 = r.fork(1);
        assert_ne!(c1.next_u64(), c2.next_u64());
    }
}
