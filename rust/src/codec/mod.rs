//! Composable per-chunk codec chains (the crate's one "field → bytes"
//! surface).
//!
//! Historically the crate exposed three disjoint ways to turn a field into
//! bytes: the [`Compressor`](crate::compressors::Compressor) trait, the
//! [`crate::correction`] free functions driven by [`FfczConfig`], and a
//! closed store-codec enum that could only express two relative bounds.
//! This module unifies them, zarrs-style, into one chain model:
//!
//! * **array→bytes** ([`ArrayStage`]) — raw f64, or any *registered* base
//!   compressor (built-ins plus anything added at runtime with
//!   [`register_codec`], no central enum to edit);
//! * **FFCz correction** ([`CorrectionStage`], optional) — the dual-domain
//!   POCS stage carrying a **full** [`FfczConfig`]: absolute, relative,
//!   and power-spectrum bounds, iteration cap, quantization retries;
//! * **bytes→bytes** ([`BytesCodec`] stages) — the lossless backend
//!   family, also registry-extensible.
//!
//! A chain is described by a serializable, versioned [`CodecChainSpec`]
//! (stored in the manifest v2 chain table, see [`crate::store::manifest`])
//! and executed by a [`CodecChain`], which is `Send + Sync` and shared
//! across the store's worker threads.
//!
//! ```
//! use ffcz::codec::{CodecChain, CodecChainSpec};
//! use ffcz::correction::FfczConfig;
//! use ffcz::data::synth::grf::GrfBuilder;
//!
//! let chunk = GrfBuilder::new(&[16, 16]).lognormal(1.0).seed(1).build();
//! let spec = CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3));
//! let chain = CodecChain::from_spec(&spec).unwrap();
//!
//! let enc = chain.encode_chunk(&chunk).unwrap();
//! assert!(enc.stats.spatial_ok && enc.stats.frequency_ok);
//! let dec = chain
//!     .decode_chunk(&enc.bytes, chunk.shape(), chunk.precision())
//!     .unwrap();
//! assert_eq!(dec.shape(), chunk.shape());
//!
//! // The spec is self-describing and round-trips through bytes.
//! let bytes = spec.to_bytes();
//! let mut pos = 0;
//! assert_eq!(CodecChainSpec::from_bytes(&bytes, &mut pos).unwrap(), spec);
//! ```

pub mod registry;
pub mod spec;

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::compressors::{Compressor, ErrorBound};
use crate::correction::{
    self, BoundSpec, CorrectionScratch, CorrectionStats, EditsBlock, FfczArchive, FfczConfig,
};
use crate::data::{Field, Precision};

pub use registry::{
    build_bytes_codec, build_compressor, bytes_codec_names, compressor_names, register_bytes_codec,
    register_codec, require_bytes_codec, require_compressor, BytesCodec,
};
pub use spec::{ArrayStage, BytesStage, CodecChainSpec, CorrectionStage, CHAIN_SPEC_VERSION};

/// Dual-domain verification outcome of one chunk, recorded at encode time
/// and persisted per chunk in the store manifest.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChunkStats {
    pub spatial_ok: bool,
    pub frequency_ok: bool,
    /// max |ε_n| / E_n over the chunk (≤ 1 is in-bound).
    pub max_spatial_ratio: f64,
    /// max ‖δ_k‖∞ / Δ_k over the chunk (≤ 1 is in-bound).
    pub max_frequency_ratio: f64,
    /// POCS iterations spent correcting this chunk.
    pub pocs_iterations: u32,
}

impl ChunkStats {
    /// Stats of a bit-exact (lossless) chunk.
    pub fn exact() -> Self {
        Self {
            spatial_ok: true,
            frequency_ok: true,
            max_spatial_ratio: 0.0,
            max_frequency_ratio: 0.0,
            pocs_iterations: 0,
        }
    }
}

/// Per-chunk encode measurements beyond the manifest-persisted
/// [`ChunkStats`]: stage wall times and retry-ladder outcomes. In-memory
/// only — the `.ffcz` wire format is unchanged; the store writer lifts
/// this into [`crate::store::StoreWriteReport`] chunk reports and the
/// `archive create --stats` table.
#[derive(Debug, Clone, Copy, Default)]
pub struct ChunkEncodeDetail {
    /// Uncompressed chunk size in bytes (`len · 8`).
    pub bytes_in: usize,
    /// Base-compressor stage (compress + probe decompress).
    pub base_compress: std::time::Duration,
    /// FFCz POCS correction (the whole quantization retry ladder).
    pub correct: std::time::Duration,
    /// Write-time dual-domain verification through the real decode path.
    pub verify: std::time::Duration,
    /// bytes→bytes lossless stages (zero when the chain has none).
    pub lossless: std::time::Duration,
    /// Whole-chunk encode wall time.
    pub total: std::time::Duration,
    /// Quantization retry-ladder attempts consumed (0 without correction).
    pub quant_attempts: u32,
    /// Whether the raw-edit fallback fired for this chunk.
    pub used_raw_fallback: bool,
}

/// One encoded chunk plus the verification stats recorded in the manifest
/// and the in-memory encode measurements.
#[derive(Debug, Clone)]
pub struct EncodedChunk {
    pub bytes: Vec<u8>,
    pub stats: ChunkStats,
    pub detail: ChunkEncodeDetail,
}

/// Registered-counter handles for the encode path, fetched once.
struct EncodeMetrics {
    chunks: crate::telemetry::Counter,
    pocs_iters: crate::telemetry::Counter,
    quant_attempts: crate::telemetry::Counter,
    raw_fallbacks: crate::telemetry::Counter,
    bytes_in: crate::telemetry::Counter,
    bytes_out: crate::telemetry::Counter,
    chunk_ns: crate::telemetry::Histogram,
}

fn encode_metrics() -> &'static EncodeMetrics {
    static METRICS: std::sync::OnceLock<EncodeMetrics> = std::sync::OnceLock::new();
    METRICS.get_or_init(|| EncodeMetrics {
        chunks: crate::telemetry::counter("store.encode.chunks"),
        pocs_iters: crate::telemetry::counter("store.encode.pocs_iters"),
        quant_attempts: crate::telemetry::counter("store.encode.quant_attempts"),
        raw_fallbacks: crate::telemetry::counter("store.encode.raw_fallbacks"),
        bytes_in: crate::telemetry::counter("store.encode.bytes_in"),
        bytes_out: crate::telemetry::counter("store.encode.bytes_out"),
        chunk_ns: crate::telemetry::histogram("store.encode.chunk_ns"),
    })
}

struct DecodeMetrics {
    chunks: crate::telemetry::Counter,
    chunk_ns: crate::telemetry::Histogram,
}

fn decode_metrics() -> &'static DecodeMetrics {
    static METRICS: std::sync::OnceLock<DecodeMetrics> = std::sync::OnceLock::new();
    METRICS.get_or_init(|| DecodeMetrics {
        chunks: crate::telemetry::counter("store.decode.chunks"),
        chunk_ns: crate::telemetry::histogram("store.decode.chunk_ns"),
    })
}

/// An executable codec chain: a validated [`CodecChainSpec`] with its
/// stages resolved against the registries. Shareable across worker
/// threads.
pub struct CodecChain {
    spec: CodecChainSpec,
    /// Resolved base compressor (base-compressor array stage only).
    base: Option<Box<dyn Compressor>>,
    /// Resolved bytes→bytes stages, encode order.
    bytes: Vec<Arc<dyn BytesCodec>>,
}

impl CodecChain {
    /// Resolve and validate a spec against the codec registries. Unknown
    /// stage names fail here with the full known-name list.
    pub fn from_spec(spec: &CodecChainSpec) -> Result<Self> {
        spec.validate_shape()?;
        let base = match &spec.array {
            ArrayStage::RawF64 => None,
            ArrayStage::Base { name, .. } => Some(registry::require_compressor(name)?),
        };
        let bytes = spec
            .bytes
            .iter()
            .map(|stage| registry::require_bytes_codec(&stage.name))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            spec: spec.clone(),
            base,
            bytes,
        })
    }

    /// The chain's serializable description.
    pub fn spec(&self) -> &CodecChainSpec {
        &self.spec
    }

    /// One-line human description.
    pub fn describe(&self) -> String {
        self.spec.describe()
    }

    /// Encode one chunk, verifying the advertised bounds; the outcome is
    /// recorded in the returned [`ChunkStats`]. Transform state (plan
    /// handles, FFT workspace, spectrum buffers) is built per call; batch
    /// encoders — the store's chunk workers — should hold one
    /// [`CorrectionScratch`] per worker and call
    /// [`CodecChain::encode_chunk_with_scratch`] so the state warms once
    /// per chunk shape and is reused across chunks.
    pub fn encode_chunk(&self, chunk: &Field) -> Result<EncodedChunk> {
        self.encode_chunk_with_scratch(chunk, &mut CorrectionScratch::new())
    }

    /// [`CodecChain::encode_chunk`] with caller-owned correction scratch.
    /// Bytes are bit-identical to the fresh-state entry point (scratch
    /// contents never influence results); after warm-up on a chunk shape
    /// the correction stage performs zero scratch allocations per chunk
    /// ([`CorrectionScratch::allocation_events`] is the gauge).
    pub fn encode_chunk_with_scratch(
        &self,
        chunk: &Field,
        scratch: &mut CorrectionScratch,
    ) -> Result<EncodedChunk> {
        let t_chunk = std::time::Instant::now();
        let mut detail = ChunkEncodeDetail {
            bytes_in: chunk.len() * 8,
            ..Default::default()
        };
        let (payload, stats) = match &self.spec.array {
            ArrayStage::RawF64 => {
                let mut raw = Vec::with_capacity(chunk.len() * 8);
                for &v in chunk.data() {
                    raw.extend_from_slice(&v.to_le_bytes());
                }
                (raw, ChunkStats::exact())
            }
            ArrayStage::Base { name, spatial } => {
                // from_spec always resolves `base` for an ArrayStage::Base
                // spec; this expect is a constructor invariant, not input.
                // ffcz-lint: allow(panic-policy)
                let base = self.base.as_ref().expect("base stage resolved in from_spec");
                match self.spec.ffcz_config() {
                    Some(cfg) => {
                        self.encode_ffcz(chunk, name, base.as_ref(), &cfg, scratch, &mut detail)?
                    }
                    None => {
                        let _span = crate::telemetry::span("store.chunk.base_compress");
                        let t = std::time::Instant::now();
                        let out = encode_base_only(chunk, name, base.as_ref(), spatial)?;
                        detail.base_compress = t.elapsed();
                        out
                    }
                }
            }
        };
        let mut bytes = payload;
        if !self.bytes.is_empty() {
            let _span = crate::telemetry::span("store.chunk.lossless");
            let t = std::time::Instant::now();
            for stage in &self.bytes {
                bytes = stage.encode(&bytes)?;
            }
            detail.lossless = t.elapsed();
        }
        detail.total = t_chunk.elapsed();
        let metrics = encode_metrics();
        metrics.chunks.incr();
        metrics.pocs_iters.add(stats.pocs_iterations as u64);
        metrics.quant_attempts.add(detail.quant_attempts as u64);
        if detail.used_raw_fallback {
            metrics.raw_fallbacks.incr();
        }
        metrics.bytes_in.add(detail.bytes_in as u64);
        metrics.bytes_out.add(bytes.len() as u64);
        metrics.chunk_ns.record_duration(detail.total);
        Ok(EncodedChunk {
            bytes,
            stats,
            detail,
        })
    }

    fn encode_ffcz(
        &self,
        chunk: &Field,
        name: &str,
        base: &dyn Compressor,
        cfg: &FfczConfig,
        scratch: &mut CorrectionScratch,
        detail: &mut ChunkEncodeDetail,
    ) -> Result<(Vec<u8>, ChunkStats)> {
        let bound = error_bound(&cfg.spatial);
        let span = crate::telemetry::span("store.chunk.base_compress");
        let t = std::time::Instant::now();
        let payload = base.compress(chunk, bound)?;
        let recon0 = base.decompress(&payload)?;
        detail.base_compress = t.elapsed();
        drop(span);
        // The archive records the *registry* name, so decode resolves
        // runtime-registered compressors even when their `name()` differs.
        let span = crate::telemetry::span("store.chunk.pocs_correct");
        let t = std::time::Instant::now();
        let archive = correction::correct_reconstruction_with_scratch(
            chunk, &recon0, name, payload, cfg, scratch,
        )?;
        detail.correct = t.elapsed();
        detail.quant_attempts = archive.stats.quant_attempts as u32;
        detail.used_raw_fallback = archive.stats.used_raw_fallback;
        drop(span);
        // Dual-domain verification against the original chunk; the outcome
        // is recorded per chunk in the manifest. The base payload is
        // decoded *again* from the stored bytes on purpose — verifying the
        // real decode path (not the encoder's in-hand reconstruction)
        // keeps the write-time guarantee honest even for a registered
        // compressor whose decompress disagrees with its encoder — while
        // the edit application and verification transforms run through the
        // worker's scratch.
        let span = crate::telemetry::span("store.chunk.verify");
        let t = std::time::Instant::now();
        let base_recon = base.decompress(&archive.base_payload)?;
        let recon =
            correction::apply::apply_edits_with_scratch(&base_recon, &archive.edits, scratch)?;
        let report = correction::verify_with_scratch(chunk, &recon, cfg, scratch);
        detail.verify = t.elapsed();
        drop(span);
        let stats = ChunkStats {
            spatial_ok: report.spatial_ok,
            frequency_ok: report.frequency_ok,
            max_spatial_ratio: report.max_spatial_ratio,
            max_frequency_ratio: report.max_frequency_ratio,
            pocs_iterations: archive.stats.iterations as u32,
        };
        Ok((archive.to_bytes(), stats))
    }

    /// Decode a chunk; `shape`/`precision` come from the manifest and the
    /// decoded field must match both.
    pub fn decode_chunk(
        &self,
        bytes: &[u8],
        shape: &[usize],
        precision: Precision,
    ) -> Result<Field> {
        self.decode_chunk_with_scratch(bytes, shape, precision, &mut CorrectionScratch::new())
    }

    /// [`CodecChain::decode_chunk`] with caller-owned correction scratch.
    /// Output is bit-identical to the fresh-state entry point; batch
    /// decoders (store read workers, server request handlers) reuse one
    /// scratch so the inverse-transform state warms once per chunk shape.
    pub fn decode_chunk_with_scratch(
        &self,
        bytes: &[u8],
        shape: &[usize],
        precision: Precision,
        scratch: &mut CorrectionScratch,
    ) -> Result<Field> {
        let _span = crate::telemetry::span("store.chunk.decode").arg("bytes", bytes.len() as u64);
        let t = std::time::Instant::now();
        let field = self.decode_chunk_inner(bytes, shape, precision, scratch)?;
        let metrics = decode_metrics();
        metrics.chunks.incr();
        metrics.chunk_ns.record_duration(t.elapsed());
        Ok(field)
    }

    fn decode_chunk_inner(
        &self,
        bytes: &[u8],
        shape: &[usize],
        precision: Precision,
        scratch: &mut CorrectionScratch,
    ) -> Result<Field> {
        // Undo the bytes stages without copying when there are none (the
        // default FFCz chain), keeping the hot read path allocation-free.
        let mut owned: Option<Vec<u8>> = None;
        for stage in self.bytes.iter().rev() {
            let input: &[u8] = owned.as_deref().unwrap_or(bytes);
            owned = Some(stage.decode(input)?);
        }
        let payload: &[u8] = owned.as_deref().unwrap_or(bytes);
        match &self.spec.array {
            ArrayStage::RawF64 => {
                let n: usize = shape.iter().product();
                if payload.len() != n * 8 {
                    bail!(
                        "raw-f64 chunk decodes to {} bytes, expected {}",
                        payload.len(),
                        n * 8
                    );
                }
                let data: Vec<f64> = payload
                    .chunks_exact(8)
                    .map(|c| f64::from_le_bytes(crate::encoding::fixed::exact(c)))
                    .collect();
                Ok(Field::new(shape, data, precision))
            }
            ArrayStage::Base { .. } => {
                let archive = FfczArchive::from_bytes(payload)?;
                let field = correction::decompress_with_scratch(&archive, scratch)?;
                check_decoded(&field, shape, precision)?;
                Ok(field)
            }
        }
    }
}

/// Base compressor without a correction stage: spatial bound only. The
/// payload is still framed as an [`FfczArchive`] (with an empty edit
/// block) so every base-stage chunk decodes through one path — and so v1
/// archives remain bit-compatible.
fn encode_base_only(
    chunk: &Field,
    name: &str,
    base: &dyn Compressor,
    spatial: &BoundSpec,
) -> Result<(Vec<u8>, ChunkStats)> {
    let bound = error_bound(spatial);
    let payload = base.compress(chunk, bound)?;
    let recon = base.decompress(&payload)?;
    let e = bound.absolute_for(chunk);
    let max_err = chunk
        .data()
        .iter()
        .zip(recon.data())
        .map(|(x, r)| (r - x).abs())
        .fold(0.0f64, f64::max);
    let archive = FfczArchive {
        base_name: name.to_string(),
        base_payload: payload,
        edits: EditsBlock::Raw {
            n: chunk.len(),
            spat: Vec::new(),
            freq: Vec::new(),
        },
        stats: CorrectionStats {
            converged: true,
            ..CorrectionStats::default()
        },
    };
    // `frequency_ok = true, ratio 0` records "not requested".
    let stats = ChunkStats {
        spatial_ok: max_err <= e,
        frequency_ok: true,
        max_spatial_ratio: max_err / e.max(f64::MIN_POSITIVE),
        max_frequency_ratio: 0.0,
        pocs_iterations: 0,
    };
    Ok((archive.to_bytes(), stats))
}

fn error_bound(spec: &BoundSpec) -> ErrorBound {
    match *spec {
        BoundSpec::Absolute(v) => ErrorBound::Absolute(v),
        BoundSpec::Relative(r) => ErrorBound::Relative(r),
    }
}

fn check_decoded(field: &Field, shape: &[usize], precision: Precision) -> Result<()> {
    if field.shape() != shape {
        bail!(
            "decoded chunk shape {:?} does not match manifest {:?}",
            field.shape(),
            shape
        );
    }
    // The base payload carries its own precision tag; a disagreement with
    // the manifest means the container was tampered with or mis-assembled.
    if field.precision() != precision {
        bail!(
            "decoded chunk precision '{}' does not match manifest '{}'",
            field.precision().name(),
            precision.name()
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::grf::GrfBuilder;

    fn grf_chunk() -> Field {
        GrfBuilder::new(&[8, 8]).lognormal(1.0).seed(11).build()
    }

    #[test]
    fn lossless_chain_is_bit_exact() {
        let chunk = grf_chunk();
        let chain = CodecChain::from_spec(&CodecChainSpec::lossless()).unwrap();
        let enc = chain.encode_chunk(&chunk).unwrap();
        assert!(enc.stats.spatial_ok && enc.stats.frequency_ok);
        let dec = chain
            .decode_chunk(&enc.bytes, chunk.shape(), chunk.precision())
            .unwrap();
        assert_eq!(dec.data(), chunk.data());
    }

    #[test]
    fn ffcz_chain_roundtrips_within_bounds() {
        let chunk = grf_chunk();
        let spec = CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3));
        let chain = CodecChain::from_spec(&spec).unwrap();
        let enc = chain.encode_chunk(&chunk).unwrap();
        assert!(enc.stats.spatial_ok && enc.stats.frequency_ok);
        assert!(enc.stats.max_spatial_ratio <= 1.0 + 1e-9);
        let dec = chain
            .decode_chunk(&enc.bytes, chunk.shape(), chunk.precision())
            .unwrap();
        assert_eq!(dec.shape(), chunk.shape());
        let e = chunk.value_span() * 1e-3;
        for (a, b) in chunk.data().iter().zip(dec.data()) {
            assert!((a - b).abs() <= e * (1.0 + 1e-9));
        }
    }

    #[test]
    fn absolute_bound_chain_roundtrips() {
        // The legacy store codec could not express absolute bounds at all.
        let chunk = grf_chunk();
        let e = chunk.value_span() * 1e-3;
        let spec = CodecChainSpec::ffcz("sz-like", &FfczConfig::absolute(e, e));
        let chain = CodecChain::from_spec(&spec).unwrap();
        let enc = chain.encode_chunk(&chunk).unwrap();
        assert!(enc.stats.spatial_ok && enc.stats.frequency_ok, "{:?}", enc.stats);
        let dec = chain
            .decode_chunk(&enc.bytes, chunk.shape(), chunk.precision())
            .unwrap();
        for (a, b) in chunk.data().iter().zip(dec.data()) {
            assert!((a - b).abs() <= e * (1.0 + 1e-9));
        }
    }

    #[test]
    fn power_spectrum_chain_records_stats() {
        let chunk = GrfBuilder::new(&[16, 16]).lognormal(1.0).seed(6).build();
        let spec = CodecChainSpec::ffcz("sz-like", &FfczConfig::power_spectrum(1e-2, 1e-3));
        let chain = CodecChain::from_spec(&spec).unwrap();
        let enc = chain.encode_chunk(&chunk).unwrap();
        assert!(enc.stats.spatial_ok && enc.stats.frequency_ok, "{:?}", enc.stats);
        assert!(enc.stats.pocs_iterations >= 1);
        let dec = chain
            .decode_chunk(&enc.bytes, chunk.shape(), chunk.precision())
            .unwrap();
        let ps0 = crate::fourier::power_spectrum(&chunk);
        let ps1 = crate::fourier::power_spectrum(&dec);
        let max_rel = ps1.max_relative_error(&ps0);
        assert!(max_rel <= 1.1e-3, "power-spectrum rel err {max_rel}");
    }

    #[test]
    fn base_only_chain_skips_correction_but_bounds_spatially() {
        let chunk = grf_chunk();
        let spec = CodecChainSpec::base_only("sz-like", BoundSpec::Relative(1e-3));
        let chain = CodecChain::from_spec(&spec).unwrap();
        let enc = chain.encode_chunk(&chunk).unwrap();
        assert!(enc.stats.spatial_ok);
        assert!(enc.stats.frequency_ok, "frequency bound not requested");
        assert_eq!(enc.stats.pocs_iterations, 0, "no POCS in base-only mode");
        assert_eq!(enc.stats.max_frequency_ratio, 0.0);
        let dec = chain
            .decode_chunk(&enc.bytes, chunk.shape(), chunk.precision())
            .unwrap();
        let e = chunk.value_span() * 1e-3;
        for (a, b) in chunk.data().iter().zip(dec.data()) {
            assert!((a - b).abs() <= e * (1.0 + 1e-9));
        }
    }

    #[test]
    fn extra_bytes_stage_composes() {
        let chunk = grf_chunk();
        let spec = CodecChainSpec::base_only("identity", BoundSpec::Relative(1e-6))
            .with_bytes_stage("lossless");
        let chain = CodecChain::from_spec(&spec).unwrap();
        let enc = chain.encode_chunk(&chunk).unwrap();
        let dec = chain
            .decode_chunk(&enc.bytes, chunk.shape(), chunk.precision())
            .unwrap();
        assert_eq!(dec.data(), chunk.data(), "identity base is bit-exact");
    }

    #[test]
    fn unknown_stage_names_fail_actionably() {
        let spec = CodecChainSpec::base_only("nope", BoundSpec::Relative(1e-3));
        let err = CodecChain::from_spec(&spec).unwrap_err().to_string();
        assert!(err.contains("nope") && err.contains("register_codec"), "{err}");
        let spec = CodecChainSpec::lossless().with_bytes_stage("nope-bytes");
        let err = CodecChain::from_spec(&spec).unwrap_err().to_string();
        assert!(err.contains("nope-bytes"), "{err}");
    }

    #[test]
    fn decode_rejects_wrong_shape_and_precision() {
        let chunk = grf_chunk();
        let chain = CodecChain::from_spec(&CodecChainSpec::lossless()).unwrap();
        let enc = chain.encode_chunk(&chunk).unwrap();
        assert!(chain
            .decode_chunk(&enc.bytes, &[4, 4], chunk.precision())
            .is_err());

        // Regression: decode must validate the manifest precision against
        // the decoded field (it used to be silently re-tagged).
        let single = Field::new(chunk.shape(), chunk.data().to_vec(), Precision::Single);
        let spec = CodecChainSpec::base_only("identity", BoundSpec::Relative(1e-6));
        let chain = CodecChain::from_spec(&spec).unwrap();
        let enc = chain.encode_chunk(&single).unwrap();
        assert!(chain
            .decode_chunk(&enc.bytes, single.shape(), Precision::Single)
            .is_ok());
        let err = chain
            .decode_chunk(&enc.bytes, single.shape(), Precision::Double)
            .unwrap_err()
            .to_string();
        assert!(err.contains("precision"), "{err}");
    }
}
