//! Runtime codec registries.
//!
//! Two registries back the codec-chain subsystem:
//!
//! * **base compressors** (array→bytes) — the built-in
//!   [`crate::compressors::by_name`] family plus anything added at runtime
//!   with [`register_codec`], so new error-bounded compressors plug into
//!   chunked stores, [`crate::correction::decompress`], and the CLI without
//!   editing a central enum;
//! * **bytes→bytes codecs** — the lossless backend family, extensible with
//!   [`register_bytes_codec`].
//!
//! Both registries are process-global (`OnceLock<RwLock<…>>`): a codec
//! registered once decodes archives on every thread, matching the
//! plugin-registration model of the zarrs ecosystem. Built-in names are
//! reserved — registering over them is an error, so an archive's meaning
//! can never be silently re-bound.

use std::collections::HashMap;
use std::sync::{Arc, OnceLock, RwLock};

use anyhow::{bail, Result};

use crate::compressors::{by_name, Compressor};
use crate::encoding::{lossless_compress, lossless_decompress};
use crate::util::sync::{read, write};

/// Builder closure producing a fresh boxed compressor.
pub type CompressorBuilder = Arc<dyn Fn() -> Box<dyn Compressor> + Send + Sync>;

/// Built-in base compressor names (always resolvable, never overridable).
pub const BUILTIN_COMPRESSORS: [&str; 4] = ["sz-like", "zfp-like", "sperr-like", "identity"];

fn compressor_table() -> &'static RwLock<HashMap<String, CompressorBuilder>> {
    static TABLE: OnceLock<RwLock<HashMap<String, CompressorBuilder>>> = OnceLock::new();
    TABLE.get_or_init(|| RwLock::new(HashMap::new()))
}

/// Register a base compressor under `name` so codec chains, stores, and
/// FFCz archives can reference it. Errors if the name is reserved by a
/// built-in compressor or already registered (re-binding a name would
/// change the meaning of existing archives).
///
/// ```
/// use ffcz::codec::{register_codec, require_compressor, CodecChainSpec};
/// use ffcz::compressors::{identity::Identity, Compressor};
/// use ffcz::correction::BoundSpec;
///
/// register_codec("my-identity", || Box::new(Identity) as Box<dyn Compressor>).unwrap();
///
/// // The name now resolves everywhere codecs are looked up …
/// assert!(require_compressor("my-identity").is_ok());
/// // … including codec chains destined for store manifests.
/// let spec = CodecChainSpec::base_only("my-identity", BoundSpec::Relative(1e-6));
/// assert!(ffcz::codec::CodecChain::from_spec(&spec).is_ok());
///
/// // Built-in names are reserved; duplicates are rejected.
/// assert!(register_codec("sz-like", || Box::new(Identity) as Box<dyn Compressor>).is_err());
/// assert!(register_codec("my-identity", || Box::new(Identity) as Box<dyn Compressor>).is_err());
/// ```
pub fn register_codec<F>(name: &str, builder: F) -> Result<()>
where
    F: Fn() -> Box<dyn Compressor> + Send + Sync + 'static,
{
    if name.is_empty() {
        bail!("codec name must be non-empty");
    }
    if by_name(name).is_some() {
        bail!("codec name '{name}' is reserved by a built-in compressor");
    }
    let mut table = write(compressor_table());
    if table.contains_key(name) {
        bail!("codec '{name}' is already registered");
    }
    table.insert(name.to_string(), Arc::new(builder));
    Ok(())
}

/// Instantiate the base compressor registered under `name` (built-ins
/// first, then runtime registrations). `None` if unknown.
pub fn build_compressor(name: &str) -> Option<Box<dyn Compressor>> {
    if let Some(c) = by_name(name) {
        return Some(c);
    }
    let builder = read(compressor_table()).get(name).cloned();
    builder.map(|b| b())
}

/// Every resolvable base compressor name (built-ins then runtime
/// registrations, the latter sorted for stable error messages).
pub fn compressor_names() -> Vec<String> {
    let mut names: Vec<String> = BUILTIN_COMPRESSORS.iter().map(|s| s.to_string()).collect();
    let mut registered: Vec<String> = read(compressor_table()).keys().cloned().collect();
    registered.sort();
    names.extend(registered);
    names
}

/// Instantiate a base compressor or fail with an actionable error listing
/// every known name.
pub fn require_compressor(name: &str) -> Result<Box<dyn Compressor>> {
    build_compressor(name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown base compressor '{name}' (known: {}; add new ones with \
             ffcz::codec::register_codec)",
            compressor_names().join(", ")
        )
    })
}

/// A bytes→bytes codec stage (lossless backend family). Implementations
/// must be stateless enough to share across the store's worker threads.
pub trait BytesCodec: Send + Sync {
    /// Registry name recorded in chain specs.
    fn name(&self) -> &str;
    fn encode(&self, data: &[u8]) -> Result<Vec<u8>>;
    fn decode(&self, data: &[u8]) -> Result<Vec<u8>>;
}

/// The crate's Huffman→ZSTD lossless cascade as a chain stage.
struct LosslessBytes;

impl BytesCodec for LosslessBytes {
    fn name(&self) -> &str {
        "lossless"
    }

    fn encode(&self, data: &[u8]) -> Result<Vec<u8>> {
        Ok(lossless_compress(data))
    }

    fn decode(&self, data: &[u8]) -> Result<Vec<u8>> {
        lossless_decompress(data)
    }
}

/// Built-in bytes→bytes stage names.
pub const BUILTIN_BYTES_CODECS: [&str; 1] = ["lossless"];

fn bytes_table() -> &'static RwLock<HashMap<String, Arc<dyn BytesCodec>>> {
    static TABLE: OnceLock<RwLock<HashMap<String, Arc<dyn BytesCodec>>>> = OnceLock::new();
    TABLE.get_or_init(|| RwLock::new(HashMap::new()))
}

/// Register a bytes→bytes codec stage. Errors on reserved or duplicate
/// names, mirroring [`register_codec`].
pub fn register_bytes_codec(codec: Arc<dyn BytesCodec>) -> Result<()> {
    let name = codec.name().to_string();
    if name.is_empty() {
        bail!("bytes codec name must be non-empty");
    }
    if BUILTIN_BYTES_CODECS.contains(&name.as_str()) {
        bail!("bytes codec name '{name}' is reserved by a built-in stage");
    }
    let mut table = write(bytes_table());
    if table.contains_key(&name) {
        bail!("bytes codec '{name}' is already registered");
    }
    table.insert(name, codec);
    Ok(())
}

/// Instantiate the bytes→bytes stage registered under `name`.
pub fn build_bytes_codec(name: &str) -> Option<Arc<dyn BytesCodec>> {
    if name == "lossless" {
        return Some(Arc::new(LosslessBytes));
    }
    read(bytes_table()).get(name).cloned()
}

/// Every resolvable bytes→bytes stage name.
pub fn bytes_codec_names() -> Vec<String> {
    let mut names: Vec<String> = BUILTIN_BYTES_CODECS.iter().map(|s| s.to_string()).collect();
    let mut registered: Vec<String> = read(bytes_table()).keys().cloned().collect();
    registered.sort();
    names.extend(registered);
    names
}

/// Instantiate a bytes→bytes stage or fail with the known-name list.
pub fn require_bytes_codec(name: &str) -> Result<Arc<dyn BytesCodec>> {
    build_bytes_codec(name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown bytes codec '{name}' (known: {}; add new ones with \
             ffcz::codec::register_bytes_codec)",
            bytes_codec_names().join(", ")
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compressors::identity::Identity;

    #[test]
    fn builtins_resolve_and_are_reserved() {
        for name in BUILTIN_COMPRESSORS {
            assert!(build_compressor(name).is_some(), "{name} missing");
            assert!(
                register_codec(name, || Box::new(Identity) as Box<dyn Compressor>).is_err()
            );
        }
        assert!(build_compressor("no-such-codec").is_none());
        let err = require_compressor("no-such-codec").unwrap_err().to_string();
        assert!(err.contains("sz-like"), "error not actionable: {err}");
    }

    #[test]
    fn runtime_registration_resolves_and_rejects_duplicates() {
        register_codec("registry-test-identity", || {
            Box::new(Identity) as Box<dyn Compressor>
        })
        .unwrap();
        let c = build_compressor("registry-test-identity").unwrap();
        assert_eq!(c.name(), "identity");
        assert!(register_codec("registry-test-identity", || {
            Box::new(Identity) as Box<dyn Compressor>
        })
        .is_err());
        assert!(compressor_names().contains(&"registry-test-identity".to_string()));
    }

    #[test]
    fn lossless_bytes_stage_roundtrips() {
        let stage = require_bytes_codec("lossless").unwrap();
        let data: Vec<u8> = (0..255u8).cycle().take(4000).collect();
        let enc = stage.encode(&data).unwrap();
        assert_eq!(stage.decode(&enc).unwrap(), data);
        assert!(require_bytes_codec("no-such-stage").is_err());
    }
}
