//! Self-describing, versioned codec-chain specs.
//!
//! A [`CodecChainSpec`] is the serializable description of one per-chunk
//! codec chain: an array→bytes stage ([`ArrayStage`]), an optional FFCz
//! dual-domain correction stage ([`CorrectionStage`], carrying the *full*
//! [`FfczConfig`] parameter space — absolute/relative/power-spectrum
//! bounds, iteration cap, quantization retries), and an ordered list of
//! bytes→bytes stages. Manifest v2 stores a table of these specs plus a
//! per-chunk index into it (see [`crate::store::manifest`]).
//!
//! ## Wire format (chain spec version 1)
//!
//! ```text
//! version          u8 (= 1)
//! array stage      u8 tag: 0 = raw-f64 · 1 = base compressor
//!                  base: varint name len · name bytes · bound spec
//! correction flag  u8 (0 / 1)
//!                  if 1: frequency bound · varint max_iters ·
//!                        varint max_quant_retries
//! bytes stages     varint count, then per stage varint name len · name
//! ```
//!
//! The correction stage's `threads` field (POCS transform parallelism) is
//! an execution knob with no effect on the encoded bytes; it is **not**
//! part of the wire format and parses as 0 (auto — see
//! [`FfczConfig::threads`]).
//!
//! where a *bound spec* is `u8 tag (0 = absolute, 1 = relative) · f64 LE`
//! and a *frequency bound* is `u8 tag (0 = uniform absolute, 1 = uniform
//! relative, 2 = power-spectrum relative) · f64 LE`.
//!
//! The manifest v1 `CodecSpec` wire format is still parseable through
//! [`CodecChainSpec::from_legacy_v1_bytes`], which maps the two legacy
//! shapes (lossless; base + optional uniform relative bound) onto
//! equivalent chains.

use anyhow::{bail, Result};

use crate::correction::{BoundSpec, FfczConfig, FrequencyBound};
use crate::encoding::varint;

/// Version byte leading every serialized chain spec.
pub const CHAIN_SPEC_VERSION: u8 = 1;

/// The array→bytes stage of a codec chain.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrayStage {
    /// Raw little-endian f64 samples (bit-exact).
    RawF64,
    /// A registered error-bounded base compressor and its spatial bound
    /// (resolved per chunk; used directly in base-only chains and as the
    /// FFCz spatial bound E when a correction stage follows).
    Base {
        /// Registry name (`"sz-like"`, …, or anything added with
        /// [`crate::codec::register_codec`]).
        name: String,
        /// Spatial bound E.
        spatial: BoundSpec,
    },
}

/// The optional FFCz dual-domain correction stage. Together with the base
/// stage's spatial bound this is a complete [`FfczConfig`] — including the
/// absolute and power-spectrum frequency modes the legacy store codec
/// could not express.
#[derive(Debug, Clone)]
pub struct CorrectionStage {
    /// Frequency bound Δ (uniform absolute/relative, or power-spectrum
    /// relative — Fig. 10 mode).
    pub frequency: FrequencyBound,
    /// POCS iteration cap.
    pub max_iters: usize,
    /// Bound-shrink retry ladder for quantization.
    pub max_quant_retries: usize,
    /// OS threads for the POCS transforms (`FfczConfig::threads`; 0 =
    /// auto, cooperatively budgeted by the store writer as
    /// `available_parallelism() / workers`). An *execution* knob, not
    /// codec identity: the encoded bytes are identical for every value,
    /// so it is **not serialized** (decoders see 0) and is excluded from
    /// equality.
    pub threads: usize,
}

/// `threads` is an execution knob, not part of the codec's identity — two
/// stages that differ only in thread count produce byte-identical chunks,
/// so they compare equal (and the wire roundtrip, which drops `threads`,
/// stays an identity).
impl PartialEq for CorrectionStage {
    fn eq(&self, other: &Self) -> bool {
        self.frequency == other.frequency
            && self.max_iters == other.max_iters
            && self.max_quant_retries == other.max_quant_retries
    }
}

/// One named bytes→bytes stage.
#[derive(Debug, Clone, PartialEq)]
pub struct BytesStage {
    /// Registry name (`"lossless"`, or anything added with
    /// [`crate::codec::register_bytes_codec`]).
    pub name: String,
}

/// A composable per-chunk codec chain: array stage → optional FFCz
/// correction → bytes stages.
///
/// Specs are self-describing and round-trip through their wire encoding
/// (the manifest chain table stores exactly these bytes):
///
/// ```
/// use ffcz::codec::CodecChainSpec;
/// use ffcz::correction::FfczConfig;
///
/// let spec = CodecChainSpec::ffcz("sz-like", &FfczConfig::power_spectrum(1e-2, 1e-3))
///     .with_bytes_stage("lossless");
/// let bytes = spec.to_bytes();
/// let mut pos = 0;
/// assert_eq!(CodecChainSpec::from_bytes(&bytes, &mut pos).unwrap(), spec);
/// assert_eq!(pos, bytes.len());
///
/// // The chain implies a complete FFCz configuration.
/// let cfg = spec.ffcz_config().unwrap();
/// assert_eq!(cfg.max_iters, 200);
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct CodecChainSpec {
    pub array: ArrayStage,
    pub correction: Option<CorrectionStage>,
    /// Applied in order after the array stage on encode, reversed on
    /// decode.
    pub bytes: Vec<BytesStage>,
}

impl CodecChainSpec {
    /// Bit-exact chain: raw f64 through the lossless backend.
    pub fn lossless() -> Self {
        Self {
            array: ArrayStage::RawF64,
            correction: None,
            bytes: vec![BytesStage {
                name: "lossless".to_string(),
            }],
        }
    }

    /// Base compressor + FFCz correction with the full `cfg` parameter
    /// space (any spatial/frequency bound mode, iteration cap, retries).
    pub fn ffcz(base: &str, cfg: &FfczConfig) -> Self {
        Self {
            array: ArrayStage::Base {
                name: base.to_string(),
                spatial: cfg.spatial,
            },
            correction: Some(CorrectionStage {
                frequency: cfg.frequency.clone(),
                max_iters: cfg.max_iters,
                max_quant_retries: cfg.max_quant_retries,
                threads: cfg.threads,
            }),
            bytes: Vec::new(),
        }
    }

    /// Base compressor alone: spatial bound only, no frequency guarantee.
    pub fn base_only(base: &str, spatial: BoundSpec) -> Self {
        Self {
            array: ArrayStage::Base {
                name: base.to_string(),
                spatial,
            },
            correction: None,
            bytes: Vec::new(),
        }
    }

    /// Append a bytes→bytes stage.
    pub fn with_bytes_stage(mut self, name: &str) -> Self {
        self.bytes.push(BytesStage {
            name: name.to_string(),
        });
        self
    }

    /// The full FFCz configuration this chain implies, if it has a
    /// correction stage.
    pub fn ffcz_config(&self) -> Option<FfczConfig> {
        let correction = self.correction.as_ref()?;
        let ArrayStage::Base { spatial, .. } = &self.array else {
            return None;
        };
        Some(FfczConfig {
            spatial: *spatial,
            frequency: correction.frequency.clone(),
            max_iters: correction.max_iters,
            max_quant_retries: correction.max_quant_retries,
            threads: correction.threads.max(1),
        })
    }

    /// One-line human description (for `archive inspect`).
    pub fn describe(&self) -> String {
        let mut out = match &self.array {
            ArrayStage::RawF64 => "raw-f64 (bit-exact)".to_string(),
            ArrayStage::Base { name, spatial } => match (&self.correction, spatial) {
                (Some(c), _) => format!(
                    "{name} + FFCz ({}, {}, per chunk{})",
                    describe_bound("eb", spatial),
                    describe_frequency(&c.frequency),
                    if c.threads > 1 {
                        format!(", {} threads", c.threads)
                    } else {
                        String::new()
                    },
                ),
                (None, s) => format!(
                    "{name} ({}, per chunk, no frequency bound)",
                    describe_bound("eb", s)
                ),
            },
        };
        for stage in &self.bytes {
            out.push_str(" → ");
            out.push_str(&stage.name);
        }
        out
    }

    /// Serialize (chain spec version 1, see the module docs).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = vec![CHAIN_SPEC_VERSION];
        match &self.array {
            ArrayStage::RawF64 => out.push(0u8),
            ArrayStage::Base { name, spatial } => {
                out.push(1u8);
                varint::write(&mut out, name.len() as u64);
                out.extend_from_slice(name.as_bytes());
                write_bound(&mut out, spatial);
            }
        }
        match &self.correction {
            None => out.push(0u8),
            Some(c) => {
                out.push(1u8);
                write_frequency(&mut out, &c.frequency);
                varint::write(&mut out, c.max_iters as u64);
                varint::write(&mut out, c.max_quant_retries as u64);
            }
        }
        varint::write(&mut out, self.bytes.len() as u64);
        for stage in &self.bytes {
            varint::write(&mut out, stage.name.len() as u64);
            out.extend_from_slice(stage.name.as_bytes());
        }
        out
    }

    /// Parse a chain spec at `*pos`, advancing it.
    pub fn from_bytes(buf: &[u8], pos: &mut usize) -> Result<Self> {
        let version = read_u8(buf, pos)?;
        if version != CHAIN_SPEC_VERSION {
            bail!("unsupported codec chain spec version {version}");
        }
        let array = match read_u8(buf, pos)? {
            0 => ArrayStage::RawF64,
            1 => {
                let name = read_name(buf, pos, "base compressor")?;
                let spatial = read_bound(buf, pos)?;
                ArrayStage::Base { name, spatial }
            }
            x => bail!("unknown array stage tag {x} in codec chain spec"),
        };
        let correction = match read_u8(buf, pos)? {
            0 => None,
            1 => {
                let frequency = read_frequency(buf, pos)?;
                let max_iters = varint::read(buf, pos)? as usize;
                let max_quant_retries = varint::read(buf, pos)? as usize;
                Some(CorrectionStage {
                    frequency,
                    max_iters,
                    max_quant_retries,
                    // Execution knob, never serialized: parsed chains are
                    // on auto unless the caller overrides (decode never
                    // runs POCS, and a re-encode through the store writer
                    // budgets auto cooperatively).
                    threads: 0,
                })
            }
            x => bail!("bad correction flag {x} in codec chain spec"),
        };
        let n_stages = varint::read(buf, pos)? as usize;
        // A stage occupies ≥ 2 serialized bytes; bound allocations by the
        // (untrusted) buffer.
        if n_stages > buf.len() {
            bail!("implausible bytes stage count {n_stages}");
        }
        let mut bytes = Vec::with_capacity(n_stages);
        for _ in 0..n_stages {
            bytes.push(BytesStage {
                name: read_name(buf, pos, "bytes codec")?,
            });
        }
        let spec = Self {
            array,
            correction,
            bytes,
        };
        spec.validate_shape()?;
        Ok(spec)
    }

    /// Structural validation (stage compatibility; name resolution happens
    /// in [`crate::codec::CodecChain::from_spec`]).
    pub fn validate_shape(&self) -> Result<()> {
        if self.correction.is_some() && matches!(self.array, ArrayStage::RawF64) {
            bail!("FFCz correction stage requires a base-compressor array stage, not raw-f64");
        }
        Ok(())
    }

    /// Parse a **manifest v1** `CodecSpec` at `*pos` and lift it onto an
    /// equivalent chain. Legacy archives only ever expressed two shapes:
    ///
    /// * tag 0, lossless → raw-f64 + `lossless` bytes stage;
    /// * tag 1, base + relative spatial bound + optional uniform relative
    ///   frequency bound → base stage (+ correction stage with the v1-era
    ///   defaults `max_iters = 200`, `max_quant_retries = 3`, which is what
    ///   the v1 store encoder hard-coded).
    pub fn from_legacy_v1_bytes(buf: &[u8], pos: &mut usize) -> Result<Self> {
        match read_u8(buf, pos)? {
            0 => Ok(Self::lossless()),
            1 => {
                let base = read_name(buf, pos, "base compressor")?;
                let spatial_rel = read_f64(buf, pos)?;
                let frequency_rel = match read_u8(buf, pos)? {
                    0 => None,
                    1 => Some(read_f64(buf, pos)?),
                    x => bail!("bad frequency flag {x} in v1 codec spec"),
                };
                Ok(match frequency_rel {
                    Some(db) => Self::ffcz(&base, &FfczConfig::relative(spatial_rel, db)),
                    None => Self::base_only(&base, BoundSpec::Relative(spatial_rel)),
                })
            }
            x => bail!("unknown v1 codec spec tag {x}"),
        }
    }
}

fn describe_bound(label: &str, b: &BoundSpec) -> String {
    match b {
        BoundSpec::Absolute(v) => format!("{label} {v:.3e} abs"),
        BoundSpec::Relative(r) => format!("{label} {r:.3e} rel"),
    }
}

fn describe_frequency(f: &FrequencyBound) -> String {
    match f {
        FrequencyBound::Uniform(b) => describe_bound("db", b),
        FrequencyBound::PowerSpectrumRelative(p) => format!("power-spectrum {p:.3e} rel"),
    }
}

fn write_bound(out: &mut Vec<u8>, b: &BoundSpec) {
    match b {
        BoundSpec::Absolute(v) => {
            out.push(0u8);
            out.extend_from_slice(&v.to_le_bytes());
        }
        BoundSpec::Relative(r) => {
            out.push(1u8);
            out.extend_from_slice(&r.to_le_bytes());
        }
    }
}

fn read_bound(buf: &[u8], pos: &mut usize) -> Result<BoundSpec> {
    match read_u8(buf, pos)? {
        0 => Ok(BoundSpec::Absolute(read_f64(buf, pos)?)),
        1 => Ok(BoundSpec::Relative(read_f64(buf, pos)?)),
        x => bail!("unknown bound spec tag {x}"),
    }
}

fn write_frequency(out: &mut Vec<u8>, f: &FrequencyBound) {
    match f {
        FrequencyBound::Uniform(BoundSpec::Absolute(v)) => {
            out.push(0u8);
            out.extend_from_slice(&v.to_le_bytes());
        }
        FrequencyBound::Uniform(BoundSpec::Relative(r)) => {
            out.push(1u8);
            out.extend_from_slice(&r.to_le_bytes());
        }
        FrequencyBound::PowerSpectrumRelative(p) => {
            out.push(2u8);
            out.extend_from_slice(&p.to_le_bytes());
        }
    }
}

fn read_frequency(buf: &[u8], pos: &mut usize) -> Result<FrequencyBound> {
    match read_u8(buf, pos)? {
        0 => Ok(FrequencyBound::Uniform(BoundSpec::Absolute(read_f64(
            buf, pos,
        )?))),
        1 => Ok(FrequencyBound::Uniform(BoundSpec::Relative(read_f64(
            buf, pos,
        )?))),
        2 => Ok(FrequencyBound::PowerSpectrumRelative(read_f64(buf, pos)?)),
        x => bail!("unknown frequency bound tag {x}"),
    }
}

fn read_u8(buf: &[u8], pos: &mut usize) -> Result<u8> {
    let v = *buf
        .get(*pos)
        .ok_or_else(|| anyhow::anyhow!("truncated codec chain spec"))?;
    *pos += 1;
    Ok(v)
}

fn read_name(buf: &[u8], pos: &mut usize, what: &str) -> Result<String> {
    let len = varint::read(buf, pos)? as usize;
    if len > 255 {
        bail!("implausible {what} name length {len}");
    }
    if *pos + len > buf.len() {
        bail!("truncated {what} name");
    }
    let name = String::from_utf8(buf[*pos..*pos + len].to_vec())?;
    *pos += len;
    Ok(name)
}

/// Read a little-endian f64 at `*pos`, advancing it (shared with the
/// manifest parser).
pub(crate) fn read_f64(buf: &[u8], pos: &mut usize) -> Result<f64> {
    crate::encoding::fixed::read_f64_le(buf, pos, "codec spec f64")
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Every bound mode `FfczConfig` can express — including absolute and
    /// power-spectrum, which the legacy `CodecSpec` could not encode.
    fn exhaustive_specs() -> Vec<CodecChainSpec> {
        vec![
            CodecChainSpec::lossless(),
            CodecChainSpec::base_only("zfp-like", BoundSpec::Relative(1e-2)),
            CodecChainSpec::base_only("sperr-like", BoundSpec::Absolute(2.5e-4)),
            CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3)),
            CodecChainSpec::ffcz("sz-like", &FfczConfig::absolute(1e-4, 5e-4)),
            CodecChainSpec::ffcz("zfp-like", &FfczConfig::power_spectrum(1e-2, 1e-3)),
            CodecChainSpec::ffcz(
                "sperr-like",
                &FfczConfig {
                    spatial: BoundSpec::Absolute(3e-3),
                    frequency: FrequencyBound::Uniform(BoundSpec::Relative(2e-3)),
                    max_iters: 77,
                    max_quant_retries: 2,
                    threads: 1,
                },
            ),
            CodecChainSpec::base_only("identity", BoundSpec::Relative(1e-6))
                .with_bytes_stage("lossless"),
        ]
    }

    #[test]
    fn spec_roundtrips_every_bound_mode() {
        for spec in exhaustive_specs() {
            let bytes = spec.to_bytes();
            let mut pos = 0;
            let back = CodecChainSpec::from_bytes(&bytes, &mut pos).unwrap();
            assert_eq!(back, spec, "roundtrip failed for {}", spec.describe());
            assert_eq!(pos, bytes.len());
        }
    }

    #[test]
    fn ffcz_config_roundtrips_through_spec() {
        let cfg = FfczConfig::power_spectrum(1e-2, 1e-3);
        let spec = CodecChainSpec::ffcz("sz-like", &cfg);
        let back = spec.ffcz_config().unwrap();
        assert_eq!(back.spatial, cfg.spatial);
        assert_eq!(back.frequency, cfg.frequency);
        assert_eq!(back.max_iters, cfg.max_iters);
        assert_eq!(back.max_quant_retries, cfg.max_quant_retries);
        assert!(CodecChainSpec::lossless().ffcz_config().is_none());
    }

    #[test]
    fn threads_knob_is_execution_only() {
        // In memory, the knob propagates into the implied FfczConfig …
        let cfg = FfczConfig::relative(1e-3, 1e-3).with_threads(4);
        let spec = CodecChainSpec::ffcz("sz-like", &cfg);
        assert_eq!(spec.ffcz_config().unwrap().threads, 4);
        // … but it is not codec identity: the wire roundtrip drops it and
        // the specs still compare equal (byte-identical chunks).
        let bytes = spec.to_bytes();
        assert_eq!(
            bytes,
            CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3)).to_bytes(),
            "threads must not leak into the wire format"
        );
        let mut pos = 0;
        let back = CodecChainSpec::from_bytes(&bytes, &mut pos).unwrap();
        assert_eq!(back, spec);
        assert_eq!(back.ffcz_config().unwrap().threads, 1);
    }

    #[test]
    fn rejects_bad_bytes() {
        let mut pos = 0;
        assert!(CodecChainSpec::from_bytes(&[], &mut pos).is_err());
        let mut pos = 0;
        assert!(CodecChainSpec::from_bytes(&[99], &mut pos).is_err());
        // Correction over raw-f64 is structurally invalid.
        let mut bad = vec![CHAIN_SPEC_VERSION, 0u8, 1u8, 1u8];
        bad.extend_from_slice(&1e-3f64.to_le_bytes());
        bad.extend_from_slice(&[200, 1, 3, 0]); // varint 200 = [200, 1]
        let mut pos = 0;
        assert!(CodecChainSpec::from_bytes(&bad, &mut pos).is_err());
        // Truncation at every prefix must error, never panic.
        let bytes = CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3)).to_bytes();
        for cut in 0..bytes.len() {
            let mut pos = 0;
            assert!(
                CodecChainSpec::from_bytes(&bytes[..cut], &mut pos).is_err(),
                "prefix of {cut} bytes unexpectedly parsed"
            );
        }
    }

    #[test]
    fn legacy_v1_specs_lift_onto_chains() {
        // Hand-built v1 wire bytes: tag 0 (lossless).
        let mut pos = 0;
        let spec = CodecChainSpec::from_legacy_v1_bytes(&[0u8], &mut pos).unwrap();
        assert_eq!(spec, CodecChainSpec::lossless());

        // Tag 1: base "sz-like", eb 1e-3 rel, db 1e-3 rel.
        let mut v1 = vec![1u8, 7u8];
        v1.extend_from_slice(b"sz-like");
        v1.extend_from_slice(&1e-3f64.to_le_bytes());
        v1.push(1u8);
        v1.extend_from_slice(&1e-3f64.to_le_bytes());
        let mut pos = 0;
        let spec = CodecChainSpec::from_legacy_v1_bytes(&v1, &mut pos).unwrap();
        assert_eq!(pos, v1.len());
        assert_eq!(
            spec,
            CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3))
        );

        // Tag 1 without frequency bound → base-only chain.
        let mut v1 = vec![1u8, 8u8];
        v1.extend_from_slice(b"zfp-like");
        v1.extend_from_slice(&1e-2f64.to_le_bytes());
        v1.push(0u8);
        let mut pos = 0;
        let spec = CodecChainSpec::from_legacy_v1_bytes(&v1, &mut pos).unwrap();
        assert_eq!(
            spec,
            CodecChainSpec::base_only("zfp-like", BoundSpec::Relative(1e-2))
        );

        let mut pos = 0;
        assert!(CodecChainSpec::from_legacy_v1_bytes(&[9u8], &mut pos).is_err());
    }

    #[test]
    fn describe_names_every_stage() {
        let d = CodecChainSpec::lossless().describe();
        assert!(d.contains("raw-f64") && d.contains("lossless"), "{d}");
        let d =
            CodecChainSpec::ffcz("sz-like", &FfczConfig::power_spectrum(1e-2, 1e-3)).describe();
        assert!(d.contains("sz-like") && d.contains("power-spectrum"), "{d}");
    }
}
