//! Telemetry: unified metrics registry, span tracing, and CLI
//! diagnostics — the crate's observability spine.
//!
//! Dependency-free by construction (the crate is offline; there is no
//! `tracing` crate here): everything is `std` atomics, `OnceLock`, and
//! hand-rolled JSON. Three surfaces:
//!
//! * **Metrics** ([`registry`]) — process-wide named [`Counter`]s,
//!   [`Gauge`]s, and log-bucketed [`Histogram`]s;
//!   [`counter`]`("store.encode.pocs_iters")` returns a shared cheap
//!   handle. [`snapshot`] captures all of them as a [`Snapshot`] with a
//!   stable JSON form (`ffcz archive create --stats` prints it).
//! * **Spans** ([`trace`]) — RAII [`Span`] guards with parent linkage and
//!   per-thread buffering, exported as Chrome `trace_event` JSON via
//!   `--trace-out FILE` (load in Perfetto / `chrome://tracing`).
//!   Disabled by default and measurably free when off (a single relaxed
//!   atomic load per call site — CI gates the overhead at ≤ 2% of encode
//!   cost through the `telemetry_overhead` row of `BENCH_store.json`).
//! * **Diagnostics** ([`diag`]) — leveled `--verbose`/`--quiet` CLI
//!   output, with message counts folded into the registry.
//!
//! # Metric-name glossary
//!
//! Registered names are **stable API** — external dashboards may key on
//! them. The full glossary with semantics lives in `docs/TELEMETRY.md`;
//! the families are:
//!
//! | prefix | owner | examples |
//! |---|---|---|
//! | `store.encode.*` | [`crate::codec`] / [`crate::store::writer`] | `chunks`, `pocs_iters`, `quant_attempts`, `raw_fallbacks`, `bytes_in`, `bytes_out`, `scratch_alloc_events`, `chunk_ns` (histogram) |
//! | `store.decode.*` | [`crate::codec`] | `chunks`, `chunk_ns` (histogram) |
//! | `store.read.*` | [`crate::store::Store`] | `lru_hits`, `lru_misses`, `lru_bytes` (gauge) |
//! | `store.write.*` | [`crate::store::writer`] | `peak_payload_bytes` (gauge) |
//! | `correction.retry.*` | retry ladder in [`crate::correction`] | `attempts`, `raw_fallbacks` |
//! | `correction.pocs.*` | [`crate::correction`] POCS engine | `rfft_fallbacks` |
//! | `fourier.plan_cache.{fft,rfft,ndrfft}.*` | FFT plan caches | `hits`, `misses`, `evictions`, `bytes` (gauge), `entries` (gauge) |
//! | `diag.messages.*` | [`diag`] | `error`, `warn`, `info`, `verbose` |
//! | `trace.spans.recorded` | [`trace`] | flushed span count |
//!
//! # Example
//!
//! ```
//! use ffcz::telemetry;
//!
//! let encoded = telemetry::counter("example.items.encoded");
//! encoded.add(3);
//! let snap = telemetry::snapshot();
//! assert!(snap.counter("example.items.encoded") >= 3);
//! // Stable JSON, parseable back:
//! let parsed = telemetry::Snapshot::from_json(&snap.to_json()).unwrap();
//! assert_eq!(parsed.counter("example.items.encoded"),
//!            snap.counter("example.items.encoded"));
//! ```

pub mod diag;
pub mod registry;
pub mod trace;

pub use registry::{
    counter, gauge, histogram, snapshot, Counter, Gauge, Histogram, HistogramSnapshot, Snapshot,
};
pub use trace::{span, span_with_parent, Span, SpanEvent};
