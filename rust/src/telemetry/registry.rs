//! Process-wide metrics registry: named atomic counters, gauges, and
//! log-bucketed histograms.
//!
//! [`Counter`], [`Gauge`], and [`Histogram`] are cheap cloneable handles
//! over `Arc`'d atomics. A handle can live **unregistered** (a per-object
//! counter such as a store's LRU hit count — construct with
//! [`Counter::new`]) or be **registered** under a stable name with
//! [`counter`]/[`gauge`]/[`histogram`], which return the shared handle for
//! that name, creating it on first use. Either way the cell type is the
//! same — there is exactly one counter implementation in the crate.
//!
//! [`snapshot`] captures every registered metric into a [`Snapshot`] with
//! deterministic ordering (names are held in `BTreeMap`s), serializable as
//! stable JSON via [`Snapshot::to_json`] and parseable back with
//! [`Snapshot::from_json`].

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{anyhow, bail, Result};

use crate::util::json::{escape, Json};

/// Number of histogram buckets: bucket `i ≥ 1` holds values whose bit
/// width is `i` (i.e. `2^(i-1) ≤ v < 2^i`); bucket 0 holds zero.
pub const HISTOGRAM_BUCKETS: usize = 65;

/// A monotonically increasing `u64` counter (relaxed atomics).
#[derive(Clone, Debug, Default)]
pub struct Counter {
    cell: Arc<AtomicU64>,
}

impl Counter {
    /// A fresh unregistered counter starting at zero.
    pub fn new() -> Self {
        Self::default()
    }

    /// Increment by one.
    #[inline]
    pub fn incr(&self) {
        self.add(1);
    }

    /// Increment by `n`.
    #[inline]
    pub fn add(&self, n: u64) {
        self.cell.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    #[inline]
    pub fn get(&self) -> u64 {
        self.cell.load(Ordering::Relaxed)
    }
}

/// A last-write-wins `u64` gauge with a monotonic-max helper.
#[derive(Clone, Debug, Default)]
pub struct Gauge {
    cell: Arc<AtomicU64>,
}

impl Gauge {
    /// A fresh unregistered gauge starting at zero.
    pub fn new() -> Self {
        Self::default()
    }

    /// Set the gauge to `v`.
    #[inline]
    pub fn set(&self, v: u64) {
        self.cell.store(v, Ordering::Relaxed);
    }

    /// Raise the gauge to `v` if `v` is larger (peak tracking).
    #[inline]
    pub fn max(&self, v: u64) {
        self.cell.fetch_max(v, Ordering::Relaxed);
    }

    /// Current value.
    #[inline]
    pub fn get(&self) -> u64 {
        self.cell.load(Ordering::Relaxed)
    }
}

struct HistogramCells {
    buckets: [AtomicU64; HISTOGRAM_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

/// A log2-bucketed `u64` histogram (typically nanosecond durations):
/// recording is three relaxed atomic adds, no locks, no allocation.
#[derive(Clone)]
pub struct Histogram {
    cells: Arc<HistogramCells>,
}

impl Default for Histogram {
    fn default() -> Self {
        Self {
            cells: Arc::new(HistogramCells {
                buckets: std::array::from_fn(|_| AtomicU64::new(0)),
                count: AtomicU64::new(0),
                sum: AtomicU64::new(0),
            }),
        }
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Histogram")
            .field("count", &self.count())
            .field("sum", &self.sum())
            .finish()
    }
}

/// Bucket index for a value: bit width of `v` (0 for `v == 0`).
#[inline]
pub fn bucket_index(v: u64) -> usize {
    (u64::BITS - v.leading_zeros()) as usize
}

impl Histogram {
    /// A fresh unregistered histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one observation.
    #[inline]
    pub fn record(&self, v: u64) {
        self.cells.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.cells.count.fetch_add(1, Ordering::Relaxed);
        self.cells.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Record a duration in nanoseconds.
    #[inline]
    pub fn record_duration(&self, d: std::time::Duration) {
        self.record(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.cells.count.load(Ordering::Relaxed)
    }

    /// Sum of all observed values.
    pub fn sum(&self) -> u64 {
        self.cells.sum.load(Ordering::Relaxed)
    }

    fn snapshot(&self) -> HistogramSnapshot {
        let buckets = (0..HISTOGRAM_BUCKETS)
            .filter_map(|i| {
                let n = self.cells.buckets[i].load(Ordering::Relaxed);
                (n > 0).then_some((i as u32, n))
            })
            .collect();
        HistogramSnapshot {
            count: self.count(),
            sum: self.sum(),
            buckets,
        }
    }
}

#[derive(Default)]
struct Registry {
    counters: Mutex<BTreeMap<String, Counter>>,
    gauges: Mutex<BTreeMap<String, Gauge>>,
    histograms: Mutex<BTreeMap<String, Histogram>>,
}

fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(Registry::default)
}

/// Shared handle for the counter registered under `name` (created on
/// first use). Hot paths should fetch the handle once and keep it.
pub fn counter(name: &str) -> Counter {
    let mut map = registry().counters.lock().unwrap();
    map.entry(name.to_string()).or_default().clone()
}

/// Shared handle for the gauge registered under `name`.
pub fn gauge(name: &str) -> Gauge {
    let mut map = registry().gauges.lock().unwrap();
    map.entry(name.to_string()).or_default().clone()
}

/// Shared handle for the histogram registered under `name`.
pub fn histogram(name: &str) -> Histogram {
    let mut map = registry().histograms.lock().unwrap();
    map.entry(name.to_string()).or_default().clone()
}

/// Point-in-time values of one registered histogram.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum: u64,
    /// Sparse `(bucket index, count)` pairs, ascending by index; bucket
    /// `i ≥ 1` covers `[2^(i-1), 2^i)`, bucket 0 is exactly zero.
    pub buckets: Vec<(u32, u64)>,
}

/// Point-in-time capture of every registered metric, with deterministic
/// (sorted-by-name) ordering.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Snapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, u64>,
    pub histograms: BTreeMap<String, HistogramSnapshot>,
}

/// Capture every registered metric. Values are read with relaxed loads;
/// concurrent writers may land between reads of different metrics.
pub fn snapshot() -> Snapshot {
    let reg = registry();
    let counters = reg
        .counters
        .lock()
        .unwrap()
        .iter()
        .map(|(k, v)| (k.clone(), v.get()))
        .collect();
    let gauges = reg
        .gauges
        .lock()
        .unwrap()
        .iter()
        .map(|(k, v)| (k.clone(), v.get()))
        .collect();
    let histograms = reg
        .histograms
        .lock()
        .unwrap()
        .iter()
        .map(|(k, v)| (k.clone(), v.snapshot()))
        .collect();
    Snapshot {
        counters,
        gauges,
        histograms,
    }
}

impl Snapshot {
    /// Value of a counter in this snapshot (0 if absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Value of a gauge in this snapshot (0 if absent).
    pub fn gauge(&self, name: &str) -> u64 {
        self.gauges.get(name).copied().unwrap_or(0)
    }

    /// Counter delta against an earlier snapshot (saturating at 0).
    pub fn counter_delta(&self, earlier: &Snapshot, name: &str) -> u64 {
        self.counter(name).saturating_sub(earlier.counter(name))
    }

    /// Stable JSON: object with `counters`, `gauges`, `histograms`, every
    /// map sorted by name, histograms as sparse `[bucket, count]` pairs.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n  \"counters\": {");
        push_u64_map(&mut out, &self.counters);
        out.push_str("},\n  \"gauges\": {");
        push_u64_map(&mut out, &self.gauges);
        out.push_str("},\n  \"histograms\": {");
        let mut first = true;
        for (name, h) in &self.histograms {
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(&format!(
                "\n    \"{}\": {{\"count\": {}, \"sum\": {}, \"buckets\": [",
                escape(name),
                h.count,
                h.sum
            ));
            for (i, (bucket, n)) in h.buckets.iter().enumerate() {
                if i > 0 {
                    out.push_str(", ");
                }
                out.push_str(&format!("[{bucket}, {n}]"));
            }
            out.push_str("]}");
        }
        if !first {
            out.push_str("\n  ");
        }
        out.push_str("}\n}\n");
        out
    }

    /// Parse a document produced by [`Snapshot::to_json`].
    pub fn from_json(text: &str) -> Result<Snapshot> {
        let doc = Json::parse(text)?;
        let mut snap = Snapshot::default();
        for (key, value) in obj_fields(&doc, "snapshot")? {
            match key.as_str() {
                "counters" => snap.counters = parse_u64_map(value, "counters")?,
                "gauges" => snap.gauges = parse_u64_map(value, "gauges")?,
                "histograms" => {
                    for (name, h) in obj_fields(value, "histograms")? {
                        snap.histograms
                            .insert(name.clone(), parse_histogram(h, name)?);
                    }
                }
                other => bail!("unknown snapshot section {other:?}"),
            }
        }
        Ok(snap)
    }
}

fn push_u64_map(out: &mut String, map: &BTreeMap<String, u64>) {
    let mut first = true;
    for (name, v) in map {
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str(&format!("\n    \"{}\": {}", escape(name), v));
    }
    if !first {
        out.push_str("\n  ");
    }
}

fn obj_fields<'a>(v: &'a Json, what: &str) -> Result<&'a [(String, Json)]> {
    v.as_obj().ok_or_else(|| anyhow!("{what} is not an object"))
}

fn parse_u64_map(v: &Json, what: &str) -> Result<BTreeMap<String, u64>> {
    let mut map = BTreeMap::new();
    for (name, value) in obj_fields(v, what)? {
        let n = value
            .as_u64()
            .ok_or_else(|| anyhow!("{what}.{name} is not a u64"))?;
        map.insert(name.clone(), n);
    }
    Ok(map)
}

fn parse_histogram(v: &Json, name: &str) -> Result<HistogramSnapshot> {
    let count = v
        .get("count")
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow!("histogram {name}: missing count"))?;
    let sum = v
        .get("sum")
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow!("histogram {name}: missing sum"))?;
    let raw = v
        .get("buckets")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("histogram {name}: missing buckets"))?;
    let mut buckets = Vec::with_capacity(raw.len());
    for pair in raw {
        let pair = pair
            .as_arr()
            .ok_or_else(|| anyhow!("histogram {name}: bucket entry is not a pair"))?;
        if pair.len() != 2 {
            bail!("histogram {name}: bucket entry is not a pair");
        }
        let idx = pair[0]
            .as_u64()
            .ok_or_else(|| anyhow!("histogram {name}: bad bucket index"))?;
        let n = pair[1]
            .as_u64()
            .ok_or_else(|| anyhow!("histogram {name}: bad bucket count"))?;
        buckets.push((idx as u32, n));
    }
    Ok(HistogramSnapshot {
        count,
        sum,
        buckets,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registered_handles_share_the_cell() {
        let a = counter("test.registry.shared");
        let b = counter("test.registry.shared");
        a.add(3);
        b.incr();
        assert_eq!(a.get(), 4);
        assert_eq!(b.get(), 4);
    }

    #[test]
    fn unregistered_counters_are_independent() {
        let a = Counter::new();
        let b = Counter::new();
        a.add(2);
        assert_eq!(a.get(), 2);
        assert_eq!(b.get(), 0);
    }

    #[test]
    fn gauge_set_and_max() {
        let g = gauge("test.registry.gauge");
        g.set(10);
        g.max(5);
        assert_eq!(g.get(), 10);
        g.max(20);
        assert_eq!(g.get(), 20);
    }

    #[test]
    fn histogram_buckets_by_bit_width() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(u64::MAX), 64);
        let h = Histogram::new();
        for v in [0u64, 1, 2, 3, 1000] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 1006);
        let s = h.snapshot();
        assert_eq!(s.buckets, vec![(0, 1), (1, 1), (2, 2), (10, 1)]);
    }

    #[test]
    fn snapshot_json_round_trips() {
        counter("test.registry.json.count").add(7);
        gauge("test.registry.json.gauge").set(1234);
        let h = histogram("test.registry.json.hist");
        h.record(0);
        h.record(300);
        let snap = snapshot();
        let parsed = Snapshot::from_json(&snap.to_json()).unwrap();
        assert_eq!(parsed.counter("test.registry.json.count"), 7);
        assert_eq!(parsed.gauge("test.registry.json.gauge"), 1234);
        let hist = &parsed.histograms["test.registry.json.hist"];
        assert_eq!(hist.count, 2);
        assert_eq!(hist.sum, 300);
        // Full snapshots may differ (other tests run concurrently); the
        // sections we own must round-trip exactly.
        assert_eq!(
            parsed.counters["test.registry.json.count"],
            snap.counters["test.registry.json.count"]
        );
    }

    #[test]
    fn empty_snapshot_serializes_and_parses() {
        let empty = Snapshot::default();
        let parsed = Snapshot::from_json(&empty.to_json()).unwrap();
        assert_eq!(parsed, empty);
    }
}
