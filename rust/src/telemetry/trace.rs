//! Span tracing: RAII guards, per-thread lock-free buffers, Chrome
//! `trace_event` JSON export.
//!
//! Tracing is **disabled by default** and must be measurably free when
//! off: [`span`] is then a single relaxed atomic load returning an inert
//! guard — no clock read, no allocation, no lock. Enable with [`enable`]
//! (the CLI does this for `--trace-out`), run the workload, then
//! [`drain`] or [`write_chrome_json`].
//!
//! When enabled, each [`Span`] records a *complete event*: name, span id,
//! parent id, thread id, start, duration, and optional numeric args.
//! Parent linkage is implicit through a per-thread span stack — a span
//! opened while another is open on the same thread becomes its child —
//! or explicit via [`span_with_parent`] for cross-thread edges (a worker
//! pool span parented to the coordinator's root span). Finished spans go
//! to a thread-local buffer (no lock on the hot path) that is flushed
//! into the global collector whenever the thread's span stack empties or
//! the thread exits.
//!
//! The export format is the Chrome `trace_event` JSON array-of-`"ph":
//! "X"` form, loadable in Perfetto (<https://ui.perfetto.dev>) or
//! `chrome://tracing`; see `docs/TELEMETRY.md`.

use std::cell::RefCell;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use anyhow::{Context, Result};

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_SPAN_ID: AtomicU64 = AtomicU64::new(1);
static NEXT_THREAD_ID: AtomicU64 = AtomicU64::new(1);

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn now_ns() -> u64 {
    epoch().elapsed().as_nanos().min(u64::MAX as u128) as u64
}

fn collector() -> &'static Mutex<Vec<SpanEvent>> {
    static COLLECTOR: OnceLock<Mutex<Vec<SpanEvent>>> = OnceLock::new();
    COLLECTOR.get_or_init(|| Mutex::new(Vec::new()))
}

fn spans_recorded() -> &'static crate::telemetry::Counter {
    static COUNTER: OnceLock<crate::telemetry::Counter> = OnceLock::new();
    COUNTER.get_or_init(|| crate::telemetry::counter("trace.spans.recorded"))
}

/// One finished span (a Chrome *complete event*).
#[derive(Debug, Clone)]
pub struct SpanEvent {
    pub name: &'static str,
    /// Process-unique span id (> 0).
    pub id: u64,
    /// Id of the enclosing span, 0 for roots.
    pub parent: u64,
    /// Small stable per-thread id (assigned on a thread's first span).
    pub tid: u64,
    /// Start, nanoseconds since the trace epoch.
    pub start_ns: u64,
    /// Duration in nanoseconds.
    pub dur_ns: u64,
    /// Numeric attributes (chunk index, byte counts, …).
    pub args: Vec<(&'static str, u64)>,
}

struct ThreadBuf {
    tid: u64,
    stack: Vec<u64>,
    events: Vec<SpanEvent>,
}

impl ThreadBuf {
    fn flush(&mut self) {
        if self.events.is_empty() {
            return;
        }
        spans_recorded().add(self.events.len() as u64);
        collector().lock().unwrap().append(&mut self.events);
    }
}

impl Drop for ThreadBuf {
    fn drop(&mut self) {
        self.flush();
    }
}

thread_local! {
    static BUF: RefCell<ThreadBuf> = RefCell::new(ThreadBuf {
        tid: NEXT_THREAD_ID.fetch_add(1, Ordering::Relaxed),
        stack: Vec::new(),
        events: Vec::new(),
    });
}

/// Turn recording on. Idempotent; pins the trace epoch on first call.
pub fn enable() {
    epoch();
    ENABLED.store(true, Ordering::SeqCst);
}

/// Turn recording off. Spans already open keep recording until dropped.
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// Whether recording is currently on.
#[inline]
pub fn is_enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

struct ActiveSpan {
    name: &'static str,
    id: u64,
    parent: u64,
    start_ns: u64,
    args: Vec<(&'static str, u64)>,
}

/// RAII span guard: records a [`SpanEvent`] on drop. Inert (and free)
/// when tracing is disabled.
#[must_use = "a span measures the scope it is alive for"]
pub struct Span(Option<ActiveSpan>);

/// Open a span parented to the innermost open span on this thread (a
/// root span if none). Returns an inert guard when tracing is off.
#[inline]
pub fn span(name: &'static str) -> Span {
    if !is_enabled() {
        return Span(None);
    }
    open(name, None)
}

/// Open a span with an explicit parent id — for cross-thread edges,
/// e.g. worker-pool chunk spans parented to the writer's root span.
/// `parent == 0` makes a root span.
#[inline]
pub fn span_with_parent(name: &'static str, parent: u64) -> Span {
    if !is_enabled() {
        return Span(None);
    }
    open(name, Some(parent))
}

fn open(name: &'static str, parent: Option<u64>) -> Span {
    let id = NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed);
    let parent = BUF.with(|b| {
        let mut b = b.borrow_mut();
        let parent = parent.unwrap_or_else(|| b.stack.last().copied().unwrap_or(0));
        b.stack.push(id);
        parent
    });
    Span(Some(ActiveSpan {
        name,
        id,
        parent,
        start_ns: now_ns(),
        args: Vec::new(),
    }))
}

impl Span {
    /// This span's id (0 when inert) — pass to [`span_with_parent`].
    pub fn id(&self) -> u64 {
        self.0.as_ref().map_or(0, |s| s.id)
    }

    /// Attach a numeric attribute (no-op when inert).
    pub fn arg(mut self, key: &'static str, value: u64) -> Self {
        if let Some(active) = self.0.as_mut() {
            active.args.push((key, value));
        }
        self
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(active) = self.0.take() else {
            return;
        };
        let end_ns = now_ns();
        BUF.with(|b| {
            let mut b = b.borrow_mut();
            // Our id should be the stack top; truncate defensively in
            // case a child guard outlived its parent.
            if let Some(pos) = b.stack.iter().rposition(|&id| id == active.id) {
                b.stack.truncate(pos);
            }
            let tid = b.tid;
            b.events.push(SpanEvent {
                name: active.name,
                id: active.id,
                parent: active.parent,
                tid,
                start_ns: active.start_ns,
                dur_ns: end_ns.saturating_sub(active.start_ns),
                args: active.args,
            });
            if b.stack.is_empty() {
                b.flush();
            }
        });
    }
}

/// Flush this thread's buffer and take every collected event. Threads
/// with spans still open keep those until the spans close.
pub fn drain() -> Vec<SpanEvent> {
    BUF.with(|b| b.borrow_mut().flush());
    std::mem::take(&mut *collector().lock().unwrap())
}

/// Render events as a Chrome `trace_event` JSON array (`"ph": "X"`
/// complete events, timestamps in microseconds), sorted by start time
/// with enclosing spans before identically-timed children.
pub fn to_chrome_json(events: &[SpanEvent]) -> String {
    let mut sorted: Vec<&SpanEvent> = events.iter().collect();
    sorted.sort_by(|a, b| {
        a.start_ns
            .cmp(&b.start_ns)
            .then(b.dur_ns.cmp(&a.dur_ns))
            .then(a.id.cmp(&b.id))
    });
    let mut out = String::from("[");
    for (i, e) in sorted.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n{{\"name\": \"{}\", \"cat\": \"ffcz\", \"ph\": \"X\", \"ts\": {:.3}, \
             \"dur\": {:.3}, \"pid\": 1, \"tid\": {}, \"args\": {{\"span_id\": {}, \
             \"parent\": {}",
            e.name,
            e.start_ns as f64 / 1e3,
            e.dur_ns as f64 / 1e3,
            e.tid,
            e.id,
            e.parent
        ));
        for (key, value) in &e.args {
            out.push_str(&format!(", \"{key}\": {value}"));
        }
        out.push_str("}}");
    }
    out.push_str("\n]\n");
    out
}

/// Drain all collected spans and write them to `path` as Chrome
/// `trace_event` JSON. Returns the number of events written.
pub fn write_chrome_json(path: &Path) -> Result<usize> {
    let events = drain();
    let json = to_chrome_json(&events);
    std::fs::write(path, json)
        .with_context(|| format!("writing trace to {}", path.display()))?;
    Ok(events.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    // Trace state is process-global and unit tests share one process:
    // tests here serialize on this lock, and — because unrelated tests
    // may run encode paths concurrently while recording is on — they
    // always filter drained events down to their own span names.
    fn guard() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn drain_named(prefix: &str) -> Vec<SpanEvent> {
        drain()
            .into_iter()
            .filter(|e| e.name.starts_with(prefix))
            .collect()
    }

    #[test]
    fn disabled_spans_are_inert() {
        let _g = guard();
        disable();
        let s = span("test.noop");
        assert_eq!(s.id(), 0);
        drop(s);
        assert!(drain_named("test.noop").is_empty());
    }

    #[test]
    fn nesting_links_parents_on_one_thread() {
        let _g = guard();
        enable();
        {
            let root = span("test.nest.root");
            let root_id = root.id();
            assert!(root_id > 0);
            {
                let child = span("test.nest.child").arg("k", 7);
                assert_ne!(child.id(), root_id);
            }
        }
        disable();
        let events = drain_named("test.nest.");
        assert_eq!(events.len(), 2);
        let root = events.iter().find(|e| e.name == "test.nest.root").unwrap();
        let child = events.iter().find(|e| e.name == "test.nest.child").unwrap();
        assert_eq!(root.parent, 0);
        assert_eq!(child.parent, root.id);
        assert_eq!(child.args, vec![("k", 7)]);
        assert_eq!(root.tid, child.tid);
        assert!(root.start_ns <= child.start_ns);
        assert!(root.start_ns + root.dur_ns >= child.start_ns + child.dur_ns);
    }

    #[test]
    fn explicit_parent_crosses_threads() {
        let _g = guard();
        enable();
        let root = span("test.xthread.root");
        let root_id = root.id();
        std::thread::scope(|scope| {
            scope.spawn(|| {
                let _child = span_with_parent("test.xthread.child", root_id);
            });
        });
        drop(root);
        disable();
        let events = drain_named("test.xthread.");
        let root = events
            .iter()
            .find(|e| e.name == "test.xthread.root")
            .unwrap();
        let child = events
            .iter()
            .find(|e| e.name == "test.xthread.child")
            .unwrap();
        assert_eq!(child.parent, root.id);
        assert_ne!(child.tid, root.tid);
    }

    #[test]
    fn chrome_json_is_valid_and_sorted() {
        // Built directly — no global state involved.
        let events = vec![
            SpanEvent {
                name: "test.json.b",
                id: 2,
                parent: 1,
                tid: 1,
                start_ns: 2_500,
                dur_ns: 1_000,
                args: vec![("chunk", 3)],
            },
            SpanEvent {
                name: "test.json.a",
                id: 1,
                parent: 0,
                tid: 1,
                start_ns: 1_000,
                dur_ns: 5_000,
                args: Vec::new(),
            },
        ];
        let json = to_chrome_json(&events);
        let doc = crate::util::json::Json::parse(&json).unwrap();
        let arr = doc.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        let mut last_ts = f64::MIN;
        for e in arr {
            assert_eq!(e.get("ph").unwrap().as_str(), Some("X"));
            assert_eq!(e.get("cat").unwrap().as_str(), Some("ffcz"));
            let ts = e.get("ts").unwrap().as_f64().unwrap();
            assert!(ts >= last_ts);
            last_ts = ts;
            assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0);
            assert!(e.get("args").unwrap().get("span_id").is_some());
        }
        assert_eq!(arr[0].get("name").unwrap().as_str(), Some("test.json.a"));
        assert_eq!(arr[0].get("ts").unwrap().as_f64(), Some(1.0));
        assert_eq!(
            arr[1].get("args").unwrap().get("chunk").unwrap().as_u64(),
            Some(3)
        );
    }

    #[test]
    fn identical_start_orders_enclosing_span_first() {
        let mk = |id: u64, dur_ns: u64| SpanEvent {
            name: "test.tie",
            id,
            parent: 0,
            tid: 1,
            start_ns: 100,
            dur_ns,
            args: Vec::new(),
        };
        let json = to_chrome_json(&[mk(2, 10), mk(1, 50)]);
        let doc = crate::util::json::Json::parse(&json).unwrap();
        let arr = doc.as_arr().unwrap();
        // Longer (enclosing) span first on a start-time tie.
        assert_eq!(
            arr[0].get("args").unwrap().get("span_id").unwrap().as_u64(),
            Some(1)
        );
    }
}
