//! Leveled CLI diagnostics routed through the metrics registry.
//!
//! The `ffcz` binary resolves `--verbose` / `--quiet` once per invocation
//! into a process-wide [`Level`] ([`apply_flags`]); subcommands then emit
//! progress and summary text through [`info`] / [`verbose`] / [`warn`] /
//! [`error`] instead of bare `println!` / `eprintln!`. Primary command
//! *output* (inspect tables, verification results, requested data) is not
//! diagnostics and stays on plain stdout regardless of level.
//!
//! Every emitted message also bumps a `diag.messages.*` counter in the
//! registry, so a [`crate::telemetry::snapshot`] records how chatty a run
//! was.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use crate::telemetry::Counter;

/// Diagnostic verbosity, ordered: `Quiet < Normal < Verbose`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// Errors only (`--quiet`).
    Quiet = 0,
    /// Errors, warnings, and one-line summaries (default).
    Normal = 1,
    /// Everything, including per-stage detail (`--verbose`).
    Verbose = 2,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Normal as u8);

/// Set the process-wide diagnostic level.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current diagnostic level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Quiet,
        2 => Level::Verbose,
        _ => Level::Normal,
    }
}

/// Resolve CLI flags into a level (`--verbose` wins over `--quiet`) and
/// apply it. Returns the resolved level.
pub fn apply_flags(verbose: bool, quiet: bool) -> Level {
    let level = if verbose {
        Level::Verbose
    } else if quiet {
        Level::Quiet
    } else {
        Level::Normal
    };
    set_level(level);
    level
}

struct DiagCounters {
    error: Counter,
    warn: Counter,
    info: Counter,
    verbose: Counter,
}

fn counters() -> &'static DiagCounters {
    static COUNTERS: OnceLock<DiagCounters> = OnceLock::new();
    COUNTERS.get_or_init(|| DiagCounters {
        error: crate::telemetry::counter("diag.messages.error"),
        warn: crate::telemetry::counter("diag.messages.warn"),
        info: crate::telemetry::counter("diag.messages.info"),
        verbose: crate::telemetry::counter("diag.messages.verbose"),
    })
}

/// Unconditional error line on stderr (never suppressed).
pub fn error(msg: &str) {
    counters().error.incr();
    eprintln!("error: {msg}");
}

/// Warning on stderr, suppressed by `--quiet`.
pub fn warn(msg: &str) {
    counters().warn.incr();
    if level() >= Level::Normal {
        eprintln!("warning: {msg}");
    }
}

/// Progress/summary line on stdout, suppressed by `--quiet`.
pub fn info(msg: &str) {
    counters().info.incr();
    if level() >= Level::Normal {
        println!("{msg}");
    }
}

/// Detail line on stdout, shown only with `--verbose`.
pub fn verbose(msg: &str) {
    counters().verbose.incr();
    if level() >= Level::Verbose {
        println!("{msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flags_resolve_with_verbose_winning() {
        // Serialize against the global level; restore Normal afterwards.
        assert_eq!(apply_flags(false, false), Level::Normal);
        assert_eq!(apply_flags(false, true), Level::Quiet);
        assert_eq!(apply_flags(true, false), Level::Verbose);
        assert_eq!(apply_flags(true, true), Level::Verbose);
        assert_eq!(level(), Level::Verbose);
        set_level(Level::Normal);
    }

    #[test]
    fn messages_bump_registry_counters() {
        let before = crate::telemetry::counter("diag.messages.verbose").get();
        verbose("detail that may or may not print");
        let after = crate::telemetry::counter("diag.messages.verbose").get();
        assert!(after > before);
    }
}
