//! Byte-budgeted LRU for the process-wide FFT plan caches.
//!
//! PR 5's plan caches grew without bound — fine for a CLI run over a few
//! chunk shapes, unacceptable for a long-lived archive service decoding
//! arbitrary shapes (ROADMAP direction 1). [`PlanCache`] keeps the
//! build-outside-the-lock / first-insert-wins discipline of the original
//! caches and adds: a byte budget (approximate plan table sizes), oldest-
//! stamp eviction through a `BTreeMap` recency index (the same scheme as
//! the store's decoded-chunk LRU), and registry metrics —
//! `fourier.plan_cache.<name>.{hits,misses,evictions}` counters plus
//! `.{bytes,entries}` gauges.
//!
//! Eviction only drops the cache's *handle*: plans are `Arc`-shared, so
//! in-flight users (a [`super::NdRealFft`] holding 1-D sub-plans, a
//! worker mid-transform) keep theirs alive. The most-recently-used entry
//! is never evicted, so a single plan larger than the budget still
//! caches.

use std::collections::{BTreeMap, HashMap};
use std::hash::Hash;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::telemetry::{Counter, Gauge};

/// Default byte budget per plan cache (tables only, approximate).
pub const DEFAULT_PLAN_CACHE_BUDGET: usize = 64 << 20;

struct Slot<V> {
    value: Arc<V>,
    stamp: u64,
    bytes: usize,
}

struct Inner<K, V> {
    map: HashMap<K, Slot<V>>,
    /// Recency index: stamp → key, oldest stamp first. Stamps are unique
    /// (a per-cache logical clock), so this is a total recency order.
    order: BTreeMap<u64, K>,
    clock: u64,
    bytes: usize,
}

struct CacheMetrics {
    hits: Counter,
    misses: Counter,
    evictions: Counter,
    bytes: Gauge,
    entries: Gauge,
}

/// A byte-budgeted, LRU-evicting, metric-exporting plan cache.
pub(crate) struct PlanCache<K, V> {
    budget: AtomicUsize,
    metrics: CacheMetrics,
    inner: Mutex<Inner<K, V>>,
}

impl<K: Eq + Hash + Clone, V> PlanCache<K, V> {
    /// `name` is the registry suffix: metrics register as
    /// `fourier.plan_cache.<name>.*`.
    pub fn new(name: &str, budget: usize) -> Self {
        let metric = |kind: &str| format!("fourier.plan_cache.{name}.{kind}");
        Self {
            budget: AtomicUsize::new(budget),
            metrics: CacheMetrics {
                hits: crate::telemetry::counter(&metric("hits")),
                misses: crate::telemetry::counter(&metric("misses")),
                evictions: crate::telemetry::counter(&metric("evictions")),
                bytes: crate::telemetry::gauge(&metric("bytes")),
                entries: crate::telemetry::gauge(&metric("entries")),
            },
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                order: BTreeMap::new(),
                clock: 0,
                bytes: 0,
            }),
        }
    }

    /// Set the byte budget and evict immediately if now over it.
    pub fn set_budget(&self, bytes: usize) {
        self.budget.store(bytes, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        self.evict_to_budget(&mut inner);
    }

    /// Fetch the plan for `key`, building it with `build` on a miss.
    ///
    /// `build` runs **outside** the lock (Bluestein planning is O(m log m);
    /// holding the mutex through it would serialize every store worker on
    /// first contact with a new size) and must return the plan plus its
    /// approximate byte footprint. Racing builders do redundant work once;
    /// the first insert wins and everyone shares it.
    pub fn get_or_insert_with(&self, key: &K, build: impl FnOnce() -> (Arc<V>, usize)) -> Arc<V> {
        if let Some(found) = self.touch(key) {
            self.metrics.hits.incr();
            return found;
        }
        let (built, built_bytes) = build();
        self.metrics.misses.incr();
        let mut inner = self.inner.lock().unwrap();
        if let Some(slot) = inner.map.get(key) {
            // A racing builder inserted first; adopt its plan.
            return slot.value.clone();
        }
        inner.clock += 1;
        let stamp = inner.clock;
        inner.order.insert(stamp, key.clone());
        inner.map.insert(
            key.clone(),
            Slot {
                value: built.clone(),
                stamp,
                bytes: built_bytes,
            },
        );
        inner.bytes += built_bytes;
        self.evict_to_budget(&mut inner);
        built
    }

    /// Look up `key` and refresh its recency stamp.
    fn touch(&self, key: &K) -> Option<Arc<V>> {
        let mut inner = self.inner.lock().unwrap();
        let (value, old_stamp) = match inner.map.get(key) {
            Some(slot) => (slot.value.clone(), slot.stamp),
            None => return None,
        };
        inner.clock += 1;
        let stamp = inner.clock;
        inner.order.remove(&old_stamp);
        inner.order.insert(stamp, key.clone());
        if let Some(slot) = inner.map.get_mut(key) {
            slot.stamp = stamp;
        }
        Some(value)
    }

    /// Drop oldest entries until within budget, keeping at least the
    /// most-recently-used one. Caller holds the lock.
    fn evict_to_budget(&self, inner: &mut Inner<K, V>) {
        let budget = self.budget.load(Ordering::Relaxed);
        while inner.bytes > budget && inner.order.len() > 1 {
            let oldest = match inner.order.iter().next() {
                Some((&stamp, _)) => stamp,
                None => break,
            };
            let key = inner.order.remove(&oldest).expect("stamp just observed");
            if let Some(slot) = inner.map.remove(&key) {
                inner.bytes -= slot.bytes;
                self.metrics.evictions.incr();
            }
        }
        self.metrics.bytes.set(inner.bytes as u64);
        self.metrics.entries.set(inner.map.len() as u64);
    }

    /// Number of cached plans (for tests and diagnostics).
    pub fn entries(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    /// Approximate bytes currently cached.
    pub fn bytes(&self) -> usize {
        self.inner.lock().unwrap().bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache(budget: usize) -> PlanCache<usize, usize> {
        // Unique-ish metric names per test run are unnecessary: handles
        // are shared but values are only read through the cache itself.
        PlanCache::new("test", budget)
    }

    fn fetch(c: &PlanCache<usize, usize>, key: usize, bytes: usize) -> Arc<usize> {
        c.get_or_insert_with(&key, || (Arc::new(key * 10), bytes))
    }

    #[test]
    fn hits_share_the_same_arc() {
        let c = cache(1000);
        let a = fetch(&c, 3, 100);
        let b = fetch(&c, 3, 100);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(c.entries(), 1);
        assert_eq!(c.bytes(), 100);
    }

    #[test]
    fn evicts_oldest_when_over_budget() {
        let c = cache(250);
        fetch(&c, 1, 100);
        fetch(&c, 2, 100);
        let third = fetch(&c, 3, 100); // 300 bytes > 250 → evict key 1
        assert_eq!(c.entries(), 2);
        assert_eq!(c.bytes(), 200);
        assert_eq!(*fetch(&c, 2, 100), 20); // still cached (no rebuild)
        assert!(Arc::ptr_eq(&third, &fetch(&c, 3, 100)));
        // Key 1 was evicted: refetching rebuilds (new Arc, same value).
        let rebuilt = fetch(&c, 1, 100);
        assert_eq!(*rebuilt, 10);
    }

    #[test]
    fn touch_refreshes_recency() {
        let c = cache(250);
        fetch(&c, 1, 100);
        fetch(&c, 2, 100);
        fetch(&c, 1, 100); // touch 1: now 2 is oldest
        fetch(&c, 3, 100); // over budget → evicts 2, keeps 1 and 3
        assert_eq!(c.entries(), 2);
        let mut rebuilt = false;
        let _ = c.get_or_insert_with(&1, || {
            rebuilt = true;
            (Arc::new(0), 100)
        });
        assert!(!rebuilt, "key 1 was touched and must still be cached");
        let _ = c.get_or_insert_with(&2, || {
            rebuilt = true;
            (Arc::new(0), 100)
        });
        assert!(rebuilt, "key 2 was the LRU entry and must have been evicted");
    }

    #[test]
    fn mru_entry_survives_tiny_budget() {
        let c = cache(10);
        let a = fetch(&c, 7, 1000); // way over budget, but MRU stays
        assert_eq!(c.entries(), 1);
        assert!(Arc::ptr_eq(&a, &fetch(&c, 7, 1000)));
    }

    #[test]
    fn set_budget_evicts_immediately() {
        let c = cache(1000);
        for k in 0..5 {
            fetch(&c, k, 100);
        }
        assert_eq!(c.entries(), 5);
        c.set_budget(150);
        assert_eq!(c.entries(), 1, "only the MRU entry may remain");
    }
}
