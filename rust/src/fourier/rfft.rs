//! Real-to-complex 1-D FFTs (half spectrum, numpy `rfft`/`irfft` layout).
//!
//! A length-`n` real signal has a Hermitian spectrum (`X[n−k] = conj(X[k])`),
//! so only the `n/2 + 1` bins `0..=n/2` carry information. Computing just
//! those — and inverting from just those — halves the arithmetic and memory
//! traffic of the POCS hot loop relative to a full complex transform.
//!
//! * **Even `n`** uses the classic pack-split scheme: the real samples are
//!   packed into `n/2` complex samples (`z[j] = x[2j] + i·x[2j+1]`), a
//!   single `n/2`-point complex FFT runs (radix-2 when `n/2` is a power of
//!   two, Bluestein otherwise — so *every* even size goes through the
//!   packed form), and a twiddle pass splits the result into the half
//!   spectrum. The inverse runs the same algebra backwards.
//! * **Odd `n`** has no 2-sample packing; it falls back to one full complex
//!   transform (Bluestein) and keeps bins `0..=n/2`. Correct for every `n`,
//!   just without the 2× packing win.
//!
//! A [`RealFft`] is a *plan* (like [`Fft`]): twiddles and the inner complex
//! plan are precomputed, and the `*_with_scratch` entry points allocate
//! nothing.

use std::f64::consts::PI;

use super::{Complex, Fft, FftDirection};

/// A planned real-to-complex FFT of fixed size `n`.
///
/// Layout and normalization follow numpy: `forward` is unnormalized and
/// returns bins `0..=n/2`; `inverse` scales by `1/n`, so
/// `irfft(rfft(x)) == x`.
pub struct RealFft {
    n: usize,
    kind: RealKind,
}

enum RealKind {
    /// n == 1: X[0] = x[0].
    Tiny,
    /// Even n: pack into n/2 complex samples, transform, post-split.
    Packed {
        /// Complex plan of size n/2.
        inner: Fft,
        /// w^k = e^{-2πik/n} for k in 0..=n/2.
        twiddles: Vec<Complex>,
    },
    /// Odd n > 1: full complex transform, keep bins 0..=n/2.
    Odd {
        /// Complex plan of size n.
        inner: Fft,
    },
}

// `len` has no `is_empty` companion on purpose: the constructor asserts
// `n ≥ 1`, so a plan can never be empty.
#[allow(clippy::len_without_is_empty)]
impl RealFft {
    /// Plan a real transform of size `n` (n ≥ 1).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "real FFT size must be ≥ 1");
        let kind = if n == 1 {
            RealKind::Tiny
        } else if n % 2 == 0 {
            let m = n / 2;
            let mut twiddles = Vec::with_capacity(m + 1);
            for k in 0..=m {
                twiddles.push(Complex::from_angle(-2.0 * PI * k as f64 / n as f64));
            }
            RealKind::Packed {
                inner: Fft::new(m),
                twiddles,
            }
        } else {
            RealKind::Odd { inner: Fft::new(n) }
        };
        RealFft { n, kind }
    }

    /// Transform size (number of real samples).
    pub fn len(&self) -> usize {
        self.n
    }

    /// Number of half-spectrum bins, `n/2 + 1`.
    pub fn half_len(&self) -> usize {
        self.n / 2 + 1
    }

    /// Scratch elements required by the `*_with_scratch` entry points.
    pub fn scratch_len(&self) -> usize {
        match &self.kind {
            RealKind::Tiny => 0,
            RealKind::Packed { inner, .. } => self.n / 2 + inner.scratch_len(),
            RealKind::Odd { inner } => self.n + inner.scratch_len(),
        }
    }

    /// Approximate resident bytes of this plan's tables (split twiddles
    /// plus the inner complex plan) — the unit of account for the
    /// plan-cache byte budget.
    pub fn approx_bytes(&self) -> usize {
        let own = std::mem::size_of::<Self>();
        own + match &self.kind {
            RealKind::Tiny => 0,
            RealKind::Packed { inner, twiddles } => {
                inner.approx_bytes() + twiddles.len() * std::mem::size_of::<Complex>()
            }
            RealKind::Odd { inner } => inner.approx_bytes(),
        }
    }

    /// Forward transform: `n` real samples → `n/2 + 1` complex bins.
    /// `out.len()` must be exactly `half_len()`; `scratch.len() ≥`
    /// [`RealFft::scratch_len`]. Allocates nothing.
    pub fn forward_with_scratch(
        &self,
        input: &[f64],
        out: &mut [Complex],
        scratch: &mut [Complex],
    ) {
        assert_eq!(input.len(), self.n, "input length != plan size");
        assert_eq!(out.len(), self.half_len(), "output length != n/2 + 1");
        match &self.kind {
            RealKind::Tiny => {
                out[0] = Complex::new(input[0], 0.0);
            }
            RealKind::Packed { inner, twiddles } => {
                let m = self.n / 2;
                let (z, rest) = scratch.split_at_mut(m);
                for (j, zj) in z.iter_mut().enumerate() {
                    *zj = Complex::new(input[2 * j], input[2 * j + 1]);
                }
                inner.process_with_scratch(z, FftDirection::Forward, rest);
                // Split Z into the even/odd-sample spectra and recombine:
                //   Xe = (Z[k] + conj(Z[m−k]))/2
                //   Xo = −i·(Z[k] − conj(Z[m−k]))/2
                //   X[k] = Xe + w^k·Xo,  w = e^{−2πi/n}
                for (k, o) in out.iter_mut().enumerate() {
                    let zk = z[k % m];
                    let zmk = z[(m - k) % m].conj();
                    let xe = (zk + zmk).scale(0.5);
                    let t = (zk - zmk).scale(0.5);
                    let xo = Complex::new(t.im, -t.re); // −i·t
                    *o = xe + twiddles[k] * xo;
                }
            }
            RealKind::Odd { inner } => {
                let (buf, rest) = scratch.split_at_mut(self.n);
                for (b, &x) in buf.iter_mut().zip(input) {
                    *b = Complex::new(x, 0.0);
                }
                inner.process_with_scratch(buf, FftDirection::Forward, rest);
                out.copy_from_slice(&buf[..self.half_len()]);
            }
        }
    }

    /// Inverse transform: `n/2 + 1` complex bins → `n` real samples, with
    /// the numpy `1/n` normalization. The spectrum is taken as the half
    /// spectrum of a real signal (the Hermitian extension is implied).
    /// Allocates nothing.
    pub fn inverse_with_scratch(
        &self,
        spec: &[Complex],
        out: &mut [f64],
        scratch: &mut [Complex],
    ) {
        assert_eq!(spec.len(), self.half_len(), "spectrum length != n/2 + 1");
        assert_eq!(out.len(), self.n, "output length != plan size");
        match &self.kind {
            RealKind::Tiny => {
                out[0] = spec[0].re;
            }
            RealKind::Packed { inner, twiddles } => {
                let m = self.n / 2;
                let (z, rest) = scratch.split_at_mut(m);
                // Invert the split:
                //   Xe = (X[k] + conj(X[m−k]))/2
                //   Xo = (X[k] − conj(X[m−k]))/2 · w^{−k}
                //   Z[k] = Xe + i·Xo
                for (k, zk) in z.iter_mut().enumerate() {
                    let xk = spec[k];
                    let xmk = spec[m - k].conj();
                    let xe = (xk + xmk).scale(0.5);
                    let t = (xk - xmk).scale(0.5);
                    let xo = t * twiddles[k].conj();
                    *zk = Complex::new(xe.re - xo.im, xe.im + xo.re); // Xe + i·Xo
                }
                inner.process_with_scratch(z, FftDirection::Inverse, rest);
                for (j, zj) in z.iter().enumerate() {
                    out[2 * j] = zj.re;
                    out[2 * j + 1] = zj.im;
                }
            }
            RealKind::Odd { inner } => {
                let h = self.half_len();
                let (buf, rest) = scratch.split_at_mut(self.n);
                buf[..h].copy_from_slice(spec);
                for k in h..self.n {
                    buf[k] = spec[self.n - k].conj();
                }
                inner.process_with_scratch(buf, FftDirection::Inverse, rest);
                for (o, b) in out.iter_mut().zip(buf.iter()) {
                    *o = b.re;
                }
            }
        }
    }

    /// Out-of-place convenience wrapper around
    /// [`RealFft::forward_with_scratch`].
    pub fn forward(&self, input: &[f64]) -> Vec<Complex> {
        let mut out = vec![Complex::ZERO; self.half_len()];
        let mut scratch = vec![Complex::ZERO; self.scratch_len()];
        self.forward_with_scratch(input, &mut out, &mut scratch);
        out
    }

    /// Out-of-place convenience wrapper around
    /// [`RealFft::inverse_with_scratch`].
    pub fn inverse(&self, spec: &[Complex]) -> Vec<f64> {
        let mut out = vec![0.0f64; self.n];
        let mut scratch = vec![Complex::ZERO; self.scratch_len()];
        self.inverse_with_scratch(spec, &mut out, &mut scratch);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;

    fn random_real(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = XorShift::new(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    /// Half spectrum via the complex plan — the correctness oracle.
    fn rfft_via_complex(x: &[f64]) -> Vec<Complex> {
        let n = x.len();
        let buf: Vec<Complex> = x.iter().map(|&v| Complex::new(v, 0.0)).collect();
        let full = Fft::new(n).transform(&buf, FftDirection::Forward);
        full[..n / 2 + 1].to_vec()
    }

    fn assert_close_c(a: &[Complex], b: &[Complex], tol: f64) {
        assert_eq!(a.len(), b.len());
        let scale = b.iter().map(|c| c.abs()).fold(1.0f64, f64::max);
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            let d = (*x - *y).abs();
            assert!(d <= tol * scale, "bin {i}: {x:?} vs {y:?} (|d|={d:.3e})");
        }
    }

    #[test]
    fn matches_complex_fft_all_parities() {
        // pow2, even non-pow2 (packed + Bluestein inner), odd (fallback).
        for &n in &[1usize, 2, 4, 8, 64, 256, 6, 10, 12, 100, 30, 3, 5, 7, 45, 243] {
            let x = random_real(n, 1000 + n as u64);
            let got = RealFft::new(n).forward(&x);
            let want = rfft_via_complex(&x);
            assert_close_c(&got, &want, 1e-9);
        }
    }

    #[test]
    fn roundtrip_identity() {
        for &n in &[1usize, 2, 8, 10, 17, 100, 128, 1000, 509] {
            let x = random_real(n, 7 + n as u64);
            let plan = RealFft::new(n);
            let back = plan.inverse(&plan.forward(&x));
            let scale = x.iter().fold(1.0f64, |a, &v| a.max(v.abs()));
            for (i, (a, b)) in x.iter().zip(&back).enumerate() {
                assert!((a - b).abs() < 1e-11 * scale, "n={n} i={i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn scratch_entry_points_allocate_into_caller_buffers() {
        let n = 48;
        let x = random_real(n, 3);
        let plan = RealFft::new(n);
        // Dirty scratch must not affect the result.
        let mut out = vec![Complex::ZERO; plan.half_len()];
        let mut scratch = vec![Complex::new(1.5, -2.5); plan.scratch_len()];
        plan.forward_with_scratch(&x, &mut out, &mut scratch);
        assert_close_c(&out, &rfft_via_complex(&x), 1e-10);
        let mut back = vec![0.0f64; n];
        plan.inverse_with_scratch(&out, &mut back, &mut scratch);
        for (a, b) in x.iter().zip(&back) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn dc_and_nyquist_are_real_for_real_input() {
        let n = 64;
        let x = random_real(n, 9);
        let spec = RealFft::new(n).forward(&x);
        assert!(spec[0].im.abs() < 1e-12, "DC {:?}", spec[0]);
        assert!(spec[n / 2].im.abs() < 1e-9, "Nyquist {:?}", spec[n / 2]);
        let sum: f64 = x.iter().sum();
        assert!((spec[0].re - sum).abs() < 1e-9 * sum.abs().max(1.0));
    }

    #[test]
    fn pure_cosine_lands_in_one_bin() {
        let n = 128;
        let k0 = 9;
        let x: Vec<f64> = (0..n)
            .map(|i| (2.0 * PI * k0 as f64 * i as f64 / n as f64).cos())
            .collect();
        let spec = RealFft::new(n).forward(&x);
        for (k, c) in spec.iter().enumerate() {
            if k == k0 {
                assert!((c.re - n as f64 / 2.0).abs() < 1e-9, "bin {k}: {c:?}");
            } else {
                assert!(c.abs() < 1e-9, "leakage at {k}: {c:?}");
            }
        }
    }
}
