//! Fourier-analysis substrate: complex arithmetic, 1-D FFTs (pow-2 sizes
//! run a split-radix-family radix-4 kernel with the radix-2 oracle kept as
//! the equivalence baseline; Bluestein for arbitrary sizes),
//! real-to-complex half-spectrum transforms, N-D transforms (complex and
//! real, with a multi-threaded strided-line engine, per-axis-length gather
//! blocks, and allocation-free scratch plans), and the radially-binned
//! power spectrum used throughout the paper's evaluation.
//!
//! The paper's GPU implementation delegates to cuFFT; this crate builds the
//! transform from scratch (no FFT crate exists in the offline dependency
//! set) and validates it against a naive O(N²) DFT and analytic golden
//! vectors in this module's tests plus python golden files.
//!
//! Real fields are the common case (every POCS iteration transforms a real
//! error vector), so the hot paths run on the **half spectrum**: [`rfftn`]
//! / [`irfftn`] and the planned [`NdRealFft`] compute only the
//! `prod(shape[..d−1]) · (last/2 + 1)` non-redundant bins — half the
//! arithmetic and memory traffic of [`fftn`] — and [`HalfSpectrum`] expands
//! to the full Hermitian vector on demand.

mod complex;
mod fft;
mod ndfft;
mod ndrfft;
mod plancache;
mod power_spectrum;
mod rfft;

pub use complex::Complex;
pub use fft::{Fft, FftDirection};
pub use ndfft::{fftn, ifftn, fftn_inplace, ifftn_inplace, plan_for};
pub use ndrfft::{
    fold_full_into, for_each_full_bin, for_each_row_with_mirror, half_index_of, half_len, irfftn,
    ndrplan_for, rfftn, rplan_for, HalfSpectrum, NdFftWorkspace, NdRealFft,
};
pub use power_spectrum::{
    power_spectrum, power_spectrum_of_complex, power_spectrum_of_real, PowerSpectrum,
};
pub use plancache::DEFAULT_PLAN_CACHE_BUDGET;
pub use rfft::RealFft;

/// Bound each process-wide FFT plan cache ([`plan_for`], [`rplan_for`],
/// [`ndrplan_for`]) to approximately `bytes` of plan tables. Least-
/// recently-used plans are evicted first; `Arc`-shared handles already
/// held by callers stay valid, and the most-recently-used plan of each
/// cache is never evicted. Sizes, hits, misses, and evictions are
/// exported through the [`crate::telemetry`] registry as
/// `fourier.plan_cache.{fft,rfft,ndrfft}.*`. The default per-cache
/// budget is [`DEFAULT_PLAN_CACHE_BUDGET`].
pub fn set_plan_cache_budget(bytes: usize) {
    ndfft::set_plan_budget(bytes);
    ndrfft::set_rplan_budget(bytes);
    ndrfft::set_ndrplan_budget(bytes);
}

/// Naive O(N²) reference DFT (forward, unnormalized), used as a correctness
/// oracle for the fast transforms.
pub fn dft_naive(input: &[Complex]) -> Vec<Complex> {
    let n = input.len();
    let mut out = vec![Complex::ZERO; n];
    for (k, o) in out.iter_mut().enumerate() {
        let mut acc = Complex::ZERO;
        for (i, &x) in input.iter().enumerate() {
            let ang = -2.0 * std::f64::consts::PI * (k as f64) * (i as f64) / n as f64;
            acc += x * Complex::from_angle(ang);
        }
        *o = acc;
    }
    out
}

/// `fftshift` index mapping: shift the zero-frequency component to the
/// centre (paper §III, power-spectrum pipeline). Returns the shifted copy.
pub fn fftshift(input: &[Complex], shape: &[usize]) -> Vec<Complex> {
    let n: usize = shape.iter().product();
    assert_eq!(n, input.len());
    let mut out = vec![Complex::ZERO; n];
    let ndim = shape.len();
    let mut idx = vec![0usize; ndim];
    for (lin, &v) in input.iter().enumerate() {
        // Destination multi-index = (idx + shape/2) mod shape.
        let mut dst = 0usize;
        for d in 0..ndim {
            let s = (idx[d] + shape[d] / 2) % shape[d];
            dst = dst * shape[d] + s;
        }
        out[dst] = v;
        // Increment row-major multi-index.
        for d in (0..ndim).rev() {
            idx[d] += 1;
            if idx[d] < shape[d] {
                break;
            }
            idx[d] = 0;
        }
        let _ = lin;
    }
    out
}

/// Signed frequency index for bin `k` of an `n`-point transform
/// (`0, 1, …, n/2, -(n/2-1), …, -1` — the numpy `fftfreq` convention times `n`).
#[inline]
pub fn signed_freq(k: usize, n: usize) -> i64 {
    if k <= n / 2 {
        k as i64
    } else {
        k as i64 - n as i64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn signed_freq_convention() {
        // n = 8: 0 1 2 3 4 -3 -2 -1
        let f: Vec<i64> = (0..8).map(|k| signed_freq(k, 8)).collect();
        assert_eq!(f, vec![0, 1, 2, 3, 4, -3, -2, -1]);
        // n = 5: 0 1 2 -2 -1
        let f: Vec<i64> = (0..5).map(|k| signed_freq(k, 5)).collect();
        assert_eq!(f, vec![0, 1, 2, -2, -1]);
    }

    #[test]
    fn fftshift_1d_even() {
        let v: Vec<Complex> = (0..4).map(|i| Complex::new(i as f64, 0.0)).collect();
        let s = fftshift(&v, &[4]);
        let re: Vec<f64> = s.iter().map(|c| c.re).collect();
        assert_eq!(re, vec![2.0, 3.0, 0.0, 1.0]);
    }

    #[test]
    fn fftshift_2d_matches_numpy() {
        // numpy.fft.fftshift(np.arange(6).reshape(2,3)) == [[5,3,4],[2,0,1]]
        let v: Vec<Complex> = (0..6).map(|i| Complex::new(i as f64, 0.0)).collect();
        let s = fftshift(&v, &[2, 3]);
        let re: Vec<f64> = s.iter().map(|c| c.re).collect();
        assert_eq!(re, vec![5.0, 3.0, 4.0, 2.0, 0.0, 1.0]);
    }

    #[test]
    fn naive_dft_of_impulse_is_flat() {
        let mut x = vec![Complex::ZERO; 8];
        x[0] = Complex::new(1.0, 0.0);
        let y = dft_naive(&x);
        for c in y {
            assert!((c.re - 1.0).abs() < 1e-12 && c.im.abs() < 1e-12);
        }
    }
}
