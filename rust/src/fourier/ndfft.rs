//! N-dimensional FFTs over row-major buffers.
//!
//! The transform is applied separably along each axis. For each axis we
//! gather the strided 1-D lines into a contiguous scratch buffer, run the
//! planned 1-D FFT, and scatter back — the standard cache-friendly scheme
//! for row-major N-D transforms. Plans are cached per distinct axis length.

use std::sync::OnceLock;

use super::ndrfft::NdFftWorkspace;
use super::plancache::{PlanCache, DEFAULT_PLAN_CACHE_BUDGET};
use super::{Complex, Fft, FftDirection};

/// Process-wide FFT plan cache. The POCS loop runs two N-D transforms per
/// iteration over the same shape; rebuilding twiddle tables (and Bluestein
/// chirps for odd sizes) every call dominated small-transform cost before
/// this cache existed (see EXPERIMENTS.md §Perf). Since PR 6 the cache is
/// byte-budgeted LRU (see [`super::plancache`]) with
/// `fourier.plan_cache.fft.*` registry metrics.
fn plan_cache() -> &'static PlanCache<usize, Fft> {
    static CACHE: OnceLock<PlanCache<usize, Fft>> = OnceLock::new();
    CACHE.get_or_init(|| PlanCache::new("fft", DEFAULT_PLAN_CACHE_BUDGET))
}

/// Set the byte budget of the complex-plan cache
/// (use [`super::set_plan_cache_budget`] to set all three caches).
pub(super) fn set_plan_budget(bytes: usize) {
    plan_cache().set_budget(bytes);
}

/// Fetch (or build) the shared plan for size `n`.
///
/// The plan is built *outside* the cache lock: Bluestein planning for a
/// large odd size is O(m log m) work, and holding the global mutex through
/// it serialized every store worker on first contact with a new size.
/// Racing builders do redundant work once; the first insert wins and
/// everyone shares it.
pub fn plan_for(n: usize) -> std::sync::Arc<Fft> {
    plan_cache().get_or_insert_with(&n, || {
        let built = std::sync::Arc::new(Fft::new(n));
        let bytes = built.approx_bytes();
        (built, bytes)
    })
}

/// Forward N-D FFT (out-of-place convenience).
pub fn fftn(input: &[Complex], shape: &[usize]) -> Vec<Complex> {
    let mut buf = input.to_vec();
    fftn_inplace(&mut buf, shape);
    buf
}

/// Inverse N-D FFT (out-of-place convenience). Normalized by `1/prod(shape)`.
pub fn ifftn(input: &[Complex], shape: &[usize]) -> Vec<Complex> {
    let mut buf = input.to_vec();
    ifftn_inplace(&mut buf, shape);
    buf
}

/// Forward N-D FFT, in place.
pub fn fftn_inplace(data: &mut [Complex], shape: &[usize]) {
    transform_nd(data, shape, FftDirection::Forward);
}

/// Inverse N-D FFT, in place.
pub fn ifftn_inplace(data: &mut [Complex], shape: &[usize]) {
    transform_nd(data, shape, FftDirection::Inverse);
}

fn transform_nd(data: &mut [Complex], shape: &[usize], dir: FftDirection) {
    let n: usize = shape.iter().product();
    assert_eq!(n, data.len(), "shape {shape:?} != buffer {}", data.len());
    if n == 0 {
        return;
    }
    // The gather blocks and Bluestein pads live in a workspace so the axis
    // sweeps share them; the threaded line engine itself lives in
    // `ndrfft` (it is common to the complex and the half-spectrum paths).
    let mut ws = NdFftWorkspace::new();
    for axis in 0..shape.len() {
        let len = shape[axis];
        if len == 1 {
            continue;
        }
        let plan = plan_for(len);
        super::ndrfft::apply_axis(data, shape, axis, &plan, dir, 1, &mut ws);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fourier::dft_naive;
    use crate::util::XorShift;

    fn random(n: usize, seed: u64) -> Vec<Complex> {
        let mut rng = XorShift::new(seed);
        (0..n)
            .map(|_| Complex::new(rng.normal(), rng.normal()))
            .collect()
    }

    fn assert_close(a: &[Complex], b: &[Complex], tol: f64) {
        let scale = b.iter().map(|c| c.abs()).fold(1.0_f64, f64::max);
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((*x - *y).abs() <= tol * scale, "idx {i}: {x:?} vs {y:?}");
        }
    }

    /// Naive N-D DFT by separable 1-D naive DFTs.
    fn dft_nd_naive(input: &[Complex], shape: &[usize]) -> Vec<Complex> {
        let mut buf = input.to_vec();
        for axis in 0..shape.len() {
            let len = shape[axis];
            let stride: usize = shape[axis + 1..].iter().product();
            let total = buf.len() / len;
            let inner = stride;
            let outer = total / inner;
            for o in 0..outer {
                for i in 0..inner {
                    let base = o * len * stride + i;
                    let line: Vec<Complex> =
                        (0..len).map(|j| buf[base + j * stride]).collect();
                    let out = dft_naive(&line);
                    for (j, v) in out.into_iter().enumerate() {
                        buf[base + j * stride] = v;
                    }
                }
            }
        }
        buf
    }

    #[test]
    fn matches_naive_2d() {
        let shape = [6usize, 8];
        let x = random(48, 7);
        assert_close(&fftn(&x, &shape), &dft_nd_naive(&x, &shape), 1e-10);
    }

    #[test]
    fn matches_naive_3d_mixed_sizes() {
        let shape = [3usize, 4, 5];
        let x = random(60, 8);
        assert_close(&fftn(&x, &shape), &dft_nd_naive(&x, &shape), 1e-10);
    }

    #[test]
    fn roundtrip_3d() {
        let shape = [4usize, 8, 16];
        let x = random(shape.iter().product(), 9);
        let y = fftn(&x, &shape);
        let z = ifftn(&y, &shape);
        assert_close(&z, &x, 1e-11);
    }

    #[test]
    fn dim1_axes_are_noops() {
        let shape = [1usize, 16, 1];
        let x = random(16, 10);
        let a = fftn(&x, &shape);
        let b = fftn(&x, &[16]);
        assert_close(&a, &b, 1e-12);
    }

    #[test]
    fn separable_impulse_2d() {
        // FFT of a centered impulse is a pure phase ramp with |X|=1.
        let shape = [8usize, 8];
        let mut x = vec![Complex::ZERO; 64];
        x[0] = Complex::ONE;
        let y = fftn(&x, &shape);
        for c in y {
            assert!((c.abs() - 1.0).abs() < 1e-12);
        }
    }
}
