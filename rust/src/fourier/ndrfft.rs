//! N-dimensional real-to-complex transforms over row-major buffers, plus
//! the multi-threaded strided-line engine shared with [`super::ndfft`].
//!
//! The separable scheme (the half-spectrum analogue of `fftn`):
//!
//! 1. a planned 1-D [`RealFft`] runs along the **last** axis — each of the
//!    `prod(shape[..d−1])` contiguous real lines becomes `last/2 + 1`
//!    complex bins, so the working buffer is the *half spectrum* of
//!    `prod(shape[..d−1]) × (last/2 + 1)` elements (numpy `rfftn` layout);
//! 2. planned complex FFTs run along every leading axis of that half
//!    buffer.
//!
//! This is where the POCS hot loop gets its 2× arithmetic/traffic saving:
//! the spatial error vector is real and stays real, so the full complex
//! N-D transform of [`super::ndfft`] computes (and clips, and inverts)
//! twice the data the math requires.
//!
//! All entry points take an explicit [`NdFftWorkspace`] and a `threads`
//! count. The workspace owns every scratch buffer (gather blocks, Bluestein
//! convolution pads) and only ever grows, so steady-state transforms — the
//! POCS iterations — allocate nothing. Line transforms fan out across up to
//! `threads` OS threads (`std::thread::scope`, an atomic work index over
//! line blocks — the same worker-pool shape as
//! [`crate::store::parallel::par_try_map`]); every line is transformed by
//! exactly one thread with identical arithmetic, so the output is
//! bit-identical for every thread count.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

use super::ndfft::plan_for;
use super::plancache::{PlanCache, DEFAULT_PLAN_CACHE_BUDGET};
use super::{Complex, Fft, FftDirection, RealFft};

/// Process-wide [`RealFft`] plan cache (the real-transform analogue of
/// [`plan_for`]). Byte-budgeted LRU with `fourier.plan_cache.rfft.*`
/// registry metrics; plans are built outside the cache lock and racing
/// builders keep the first insert (see [`super::plancache`]).
fn rplan_cache() -> &'static PlanCache<usize, RealFft> {
    static CACHE: OnceLock<PlanCache<usize, RealFft>> = OnceLock::new();
    CACHE.get_or_init(|| PlanCache::new("rfft", DEFAULT_PLAN_CACHE_BUDGET))
}

/// Set the byte budget of the real-plan cache
/// (use [`super::set_plan_cache_budget`] to set all three caches).
pub(super) fn set_rplan_budget(bytes: usize) {
    rplan_cache().set_budget(bytes);
}

/// Fetch (or build) the shared real-transform plan for size `n`.
pub fn rplan_for(n: usize) -> Arc<RealFft> {
    rplan_cache().get_or_insert_with(&n, || {
        let built = Arc::new(RealFft::new(n));
        let bytes = built.approx_bytes();
        (built, bytes)
    })
}

/// Process-wide [`NdRealFft`] plan cache keyed by shape, so the encode hot
/// path ([`crate::correction`]'s retry ladder, the store's per-chunk
/// verifiers) can hold *handles* to one shared plan per chunk shape
/// instead of re-deriving the per-axis plan list on every call. Like
/// [`plan_for`]/[`rplan_for`], a byte-budgeted LRU
/// (`fourier.plan_cache.ndrfft.*` metrics); plans are built outside the
/// cache lock and racing builders keep the first insert. Eviction here
/// only drops the shape-level handle table — the 1-D sub-plans are
/// `Arc`-shared with (and accounted by) the 1-D caches.
fn ndrplan_cache() -> &'static PlanCache<Vec<usize>, NdRealFft> {
    static CACHE: OnceLock<PlanCache<Vec<usize>, NdRealFft>> = OnceLock::new();
    CACHE.get_or_init(|| PlanCache::new("ndrfft", DEFAULT_PLAN_CACHE_BUDGET))
}

/// Set the byte budget of the N-D real-plan cache
/// (use [`super::set_plan_cache_budget`] to set all three caches).
pub(super) fn set_ndrplan_budget(bytes: usize) {
    ndrplan_cache().set_budget(bytes);
}

/// Fetch (or build) the shared N-D real-transform plan for `shape`.
pub fn ndrplan_for(shape: &[usize]) -> Arc<NdRealFft> {
    let key = shape.to_vec();
    ndrplan_cache().get_or_insert_with(&key, || {
        let built = Arc::new(NdRealFft::new(shape));
        let bytes = built.approx_bytes();
        (built, bytes)
    })
}

/// Number of complex elements in the half spectrum of a real field with
/// `shape`: `prod(shape[..d−1]) · (shape[d−1]/2 + 1)`.
pub fn half_len(shape: &[usize]) -> usize {
    let d = shape.len();
    assert!(d >= 1, "scalar (0-d) transforms are not supported");
    shape[..d - 1].iter().product::<usize>() * (shape[d - 1] / 2 + 1)
}

/// Reusable scratch for the N-D transform engines: one lane per worker
/// thread, each holding a gather block for strided lines and 1-D FFT
/// scratch (Bluestein convolution pad). Lanes only ever grow, so holding a
/// workspace across POCS iterations makes the steady state allocation-free.
pub struct NdFftWorkspace {
    lanes: Vec<Lane>,
    /// Buffer-growth events since construction (lane added, gather block
    /// or 1-D scratch resized). Stable in steady state; the encode-path
    /// allocation gauge sums this into
    /// [`crate::correction::CorrectionScratch::allocation_events`].
    grow_events: u64,
}

struct Lane {
    /// Gather/scatter block for strided axis sweeps (up to [`line_block`]
    /// lines of the longest axis seen).
    block: Vec<Complex>,
    /// 1-D plan scratch (max of the sizes seen so far).
    scratch: Vec<Complex>,
}

impl NdFftWorkspace {
    pub fn new() -> Self {
        Self {
            lanes: Vec::new(),
            grow_events: 0,
        }
    }

    /// Grow (never shrink) to `lanes` lanes with at least the given block
    /// and scratch capacities.
    fn ensure(&mut self, lanes: usize, block: usize, scratch: usize) {
        while self.lanes.len() < lanes {
            self.lanes.push(Lane {
                block: Vec::new(),
                scratch: Vec::new(),
            });
            self.grow_events += 1;
        }
        for lane in &mut self.lanes[..lanes] {
            if lane.block.len() < block {
                lane.block.resize(block, Complex::ZERO);
                self.grow_events += 1;
            }
            if lane.scratch.len() < scratch {
                lane.scratch.resize(scratch, Complex::ZERO);
                self.grow_events += 1;
            }
        }
    }

    /// Total complex elements currently owned (tests assert this is stable
    /// across steady-state iterations — no per-iteration growth).
    pub fn allocated_elems(&self) -> usize {
        self.lanes
            .iter()
            .map(|l| l.block.capacity() + l.scratch.capacity())
            .sum()
    }

    /// Number of buffer-growth events so far (see the field docs). A
    /// workspace that has warmed up on a shape reports the same value
    /// after every further transform of that shape.
    pub fn grow_events(&self) -> u64 {
        self.grow_events
    }
}

impl Default for NdFftWorkspace {
    fn default() -> Self {
        Self::new()
    }
}

/// Maximum number of strided lines gathered/scattered together. Batching
/// turns the stride-`s` single-element accesses of a lone line into
/// `B`-element consecutive runs (adjacent lines differ by 1 in the inner
/// index), so each cache-line fetch serves `B` lines.
pub(crate) const LINE_BLOCK: usize = 8;

/// Lines per gather block for an axis of length `len`. A block stages
/// `B · len` complex elements (16 B each) contiguously, so long lines —
/// Bluestein axes additionally drag an `≥ 2·len`-point convolution pad
/// through the same lane — must shrink `B` to keep the working set inside
/// the L2 cache (≈ 256 KiB budget; the `kernels` bench is the measurement
/// harness, see EXPERIMENTS.md §Perf "Per-axis line blocks"). Short lines
/// keep the full 8-line block that amortizes the strided gather; the
/// floor is 2 lines (1 would forfeit batching entirely), accepting an
/// over-budget block on extreme axis lengths.
pub(crate) fn line_block(len: usize) -> usize {
    if len <= 2048 {
        LINE_BLOCK // 8 lines ≤ 256 KiB staged
    } else if len <= 4096 {
        4 // ≤ 256 KiB
    } else {
        2
    }
}

/// Raw base pointer handed to worker threads. Safety rests on the work
/// decomposition in [`run_line_item`]: distinct items address disjoint
/// element sets, so no element is ever aliased by two threads.
#[derive(Clone, Copy)]
struct SendPtr(*mut Complex);
// SAFETY: the pointer is only dereferenced inside `run_line_item`, whose
// work decomposition hands every item a disjoint element set (see its
// `# Safety` contract) and whose callers claim each item exactly once via
// a shared atomic counter — so moving the pointer to another thread can
// never produce an aliased write. The buffer itself outlives the workers:
// they run inside a `thread::scope` that borrows `data`.
unsafe impl Send for SendPtr {}

/// Apply a planned 1-D transform along `axis` of the row-major buffer
/// `data` with `shape`, fanning independent line blocks across up to
/// `threads` OS threads. Output is bit-identical for every thread count.
pub(crate) fn apply_axis(
    data: &mut [Complex],
    shape: &[usize],
    axis: usize,
    plan: &Fft,
    dir: FftDirection,
    threads: usize,
    ws: &mut NdFftWorkspace,
) {
    let len = shape[axis];
    if len <= 1 || data.is_empty() {
        return;
    }
    debug_assert_eq!(plan.len(), len, "plan size != axis length");
    // stride between successive elements along `axis`
    let stride: usize = shape[axis + 1..].iter().product();
    // Lines are enumerated by (outer, inner): outer indexes the dims before
    // `axis`, inner the dims after. Base offset = outer·len·stride + inner.
    let inner = stride;
    let outer = data.len() / (len * inner);
    // One work item = up to `lb` lines (contiguous lines when stride == 1,
    // adjacent strided lines otherwise); `lb` shrinks for long lines so
    // the staged block stays cache-resident.
    let lb = line_block(len);
    let items = if stride == 1 {
        outer.div_ceil(lb)
    } else {
        outer * inner.div_ceil(lb)
    };
    let lanes = threads.clamp(1, items.max(1));
    let block_elems = if stride == 1 { 0 } else { lb * len };
    ws.ensure(lanes, block_elems, plan.scratch_len());

    if lanes == 1 {
        let lane = &mut ws.lanes[0];
        for item in 0..items {
            // SAFETY: single thread holding `&mut data` — no aliasing.
            unsafe {
                run_line_item(data.as_mut_ptr(), item, lb, len, stride, inner, outer, plan, dir, lane)
            };
        }
        return;
    }

    let next = AtomicUsize::new(0);
    let ptr = SendPtr(data.as_mut_ptr());
    std::thread::scope(|scope| {
        for lane in ws.lanes[..lanes].iter_mut() {
            let next = &next;
            scope.spawn(move || loop {
                let item = next.fetch_add(1, Ordering::Relaxed);
                if item >= items {
                    break;
                }
                // SAFETY: distinct items address disjoint element sets of
                // `data` (see `run_line_item`), and the scope outlives
                // every worker.
                unsafe {
                    run_line_item(ptr.0, item, lb, len, stride, inner, outer, plan, dir, lane)
                };
            });
        }
    });
}

/// Execute one line-block work item (`lb` = block line count from
/// [`line_block`], fixed per axis sweep).
///
/// # Safety
///
/// `data` must be valid for `outer · len · inner` elements, and no other
/// thread may concurrently touch the elements this item addresses. Item
/// index sets are disjoint by construction (with `B = lb`): when
/// `stride == 1` item `i` owns the contiguous lines
/// `[i·B, min((i+1)·B, outer))`; otherwise item
/// `i = o·ceil(inner/B) + ib` owns offsets `o·len·stride + j·stride + t`
/// for `j in 0..len`, `t in [ib·B, min(ib·B + B, inner))`, which are
/// disjoint across distinct `(o, ib)`.
#[allow(clippy::too_many_arguments)]
unsafe fn run_line_item(
    data: *mut Complex,
    item: usize,
    lb: usize,
    len: usize,
    stride: usize,
    inner: usize,
    outer: usize,
    plan: &Fft,
    dir: FftDirection,
    lane: &mut Lane,
) {
    debug_assert!(lb > 0 && len > 0, "degenerate line block");
    debug_assert_eq!(stride, inner, "strided layout invariant");
    if stride == 1 {
        // Contiguous fast path: transform in place within each line.
        let o0 = item * lb;
        debug_assert!(o0 < outer, "item {item} outside the line range");
        let ob = lb.min(outer - o0);
        for o in o0..o0 + ob {
            let line = std::slice::from_raw_parts_mut(data.add(o * len), len);
            plan.process_with_scratch(line, dir, &mut lane.scratch);
        }
        return;
    }
    let iblocks = inner.div_ceil(lb);
    let o = item / iblocks;
    let i0 = (item % iblocks) * lb;
    debug_assert!(o < outer && i0 < inner, "item {item} outside the grid");
    let b = lb.min(inner - i0);
    let base = o * len * stride + i0;
    // Highest offset this item touches stays inside the buffer, so the
    // per-(o, ib) ownership sets in the `# Safety` contract are in bounds.
    debug_assert!(
        base + (len - 1) * stride + b <= outer * len * inner,
        "item {item} overruns the buffer"
    );
    let block = &mut lane.block;
    debug_assert!(block.len() >= b * len, "lane block smaller than the item");
    // Gather b adjacent lines: for each j the addresses
    // base + j·stride + 0..b are consecutive.
    for j in 0..len {
        let src = base + j * stride;
        for t in 0..b {
            block[t * len + j] = *data.add(src + t);
        }
    }
    for t in 0..b {
        plan.process_with_scratch(&mut block[t * len..(t + 1) * len], dir, &mut lane.scratch);
    }
    for j in 0..len {
        let dst = base + j * stride;
        for t in 0..b {
            *data.add(dst + t) = block[t * len + j];
        }
    }
}

/// A planned N-D real transform of fixed shape: one [`RealFft`] for the
/// last axis plus one cached complex [`Fft`] per leading axis, all shared
/// through the process-wide plan caches.
pub struct NdRealFft {
    shape: Vec<usize>,
    /// `shape` with the last axis replaced by `last/2 + 1`.
    half_shape: Vec<usize>,
    /// `prod(shape[..d−1])` — number of 1-D real lines along the last axis.
    rows: usize,
    rplan: Arc<RealFft>,
    lead_plans: Vec<Arc<Fft>>,
}

impl NdRealFft {
    /// Plan the transform for `shape` (row-major, every axis ≥ 1).
    pub fn new(shape: &[usize]) -> Self {
        let d = shape.len();
        assert!(d >= 1, "scalar (0-d) transforms are not supported");
        assert!(
            shape.iter().all(|&s| s >= 1),
            "every axis must be ≥ 1, got {shape:?}"
        );
        let last = shape[d - 1];
        let mut half_shape = shape.to_vec();
        half_shape[d - 1] = last / 2 + 1;
        Self {
            shape: shape.to_vec(),
            half_shape,
            rows: shape[..d - 1].iter().product(),
            rplan: rplan_for(last),
            lead_plans: shape[..d - 1].iter().map(|&n| plan_for(n)).collect(),
        }
    }

    /// The planned (full, real-space) shape.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// The half-spectrum buffer shape (`shape` with last → `last/2 + 1`).
    pub fn half_shape(&self) -> &[usize] {
        &self.half_shape
    }

    /// Approximate resident bytes owned by this plan *itself* (shape
    /// vectors + sub-plan handle table). The 1-D sub-plans are shared
    /// `Arc` handles accounted by their own caches, so they are not
    /// double-counted here.
    pub fn approx_bytes(&self) -> usize {
        std::mem::size_of::<Self>()
            + (self.shape.capacity() + self.half_shape.capacity()) * std::mem::size_of::<usize>()
            + self.lead_plans.capacity() * std::mem::size_of::<Arc<Fft>>()
    }

    /// Number of real samples, `prod(shape)`.
    pub fn len_full(&self) -> usize {
        self.rows * self.shape[self.shape.len() - 1]
    }

    /// Number of half-spectrum elements, `prod(half_shape)`.
    pub fn half_len(&self) -> usize {
        self.rows * self.half_shape[self.half_shape.len() - 1]
    }

    /// Forward transform: real `input` (len `prod(shape)`) → half spectrum
    /// `spec` (len [`NdRealFft::half_len`]). Unnormalized (numpy `rfftn`).
    pub fn forward(
        &self,
        input: &[f64],
        spec: &mut [Complex],
        threads: usize,
        ws: &mut NdFftWorkspace,
    ) {
        assert_eq!(input.len(), self.len_full(), "input length != prod(shape)");
        assert_eq!(spec.len(), self.half_len(), "spectrum length != half_len");
        self.rfft_rows(input, spec, threads, ws);
        for (axis, plan) in self.lead_plans.iter().enumerate() {
            apply_axis(
                spec,
                &self.half_shape,
                axis,
                plan.as_ref(),
                FftDirection::Forward,
                threads,
                ws,
            );
        }
    }

    /// Inverse transform: half spectrum `spec` → real `out`, normalized by
    /// `1/prod(shape)` (numpy `irfftn`). `spec` is consumed as scratch (its
    /// contents are destroyed); the spectrum is taken as the half spectrum
    /// of a real field, i.e. the Hermitian extension is implied.
    pub fn inverse(
        &self,
        spec: &mut [Complex],
        out: &mut [f64],
        threads: usize,
        ws: &mut NdFftWorkspace,
    ) {
        assert_eq!(spec.len(), self.half_len(), "spectrum length != half_len");
        assert_eq!(out.len(), self.len_full(), "output length != prod(shape)");
        for (axis, plan) in self.lead_plans.iter().enumerate().rev() {
            apply_axis(
                spec,
                &self.half_shape,
                axis,
                plan.as_ref(),
                FftDirection::Inverse,
                threads,
                ws,
            );
        }
        self.irfft_rows(spec, out, threads, ws);
    }

    /// Stage 1 of `forward`: per-row real FFT along the (contiguous) last
    /// axis, statically partitioned across threads (rows are uniform cost).
    fn rfft_rows(
        &self,
        input: &[f64],
        spec: &mut [Complex],
        threads: usize,
        ws: &mut NdFftWorkspace,
    ) {
        let last = self.shape[self.shape.len() - 1];
        let h = last / 2 + 1;
        let rows = self.rows;
        let lanes = threads.clamp(1, rows.max(1));
        ws.ensure(lanes, 0, self.rplan.scratch_len());
        if lanes == 1 {
            let lane = &mut ws.lanes[0];
            for r in 0..rows {
                self.rplan.forward_with_scratch(
                    &input[r * last..(r + 1) * last],
                    &mut spec[r * h..(r + 1) * h],
                    &mut lane.scratch,
                );
            }
            return;
        }
        let rplan = self.rplan.as_ref();
        let base = rows / lanes;
        let rem = rows % lanes;
        std::thread::scope(|scope| {
            let mut spec_rest = spec;
            let mut input_rest = input;
            for (t, lane) in ws.lanes[..lanes].iter_mut().enumerate() {
                let nrows = base + usize::from(t < rem);
                let (sp, sr) = std::mem::take(&mut spec_rest).split_at_mut(nrows * h);
                let (ip, ir) = input_rest.split_at(nrows * last);
                spec_rest = sr;
                input_rest = ir;
                scope.spawn(move || {
                    for r in 0..nrows {
                        rplan.forward_with_scratch(
                            &ip[r * last..(r + 1) * last],
                            &mut sp[r * h..(r + 1) * h],
                            &mut lane.scratch,
                        );
                    }
                });
            }
        });
    }

    /// Final stage of `inverse`: per-row inverse real FFT.
    fn irfft_rows(
        &self,
        spec: &[Complex],
        out: &mut [f64],
        threads: usize,
        ws: &mut NdFftWorkspace,
    ) {
        let last = self.shape[self.shape.len() - 1];
        let h = last / 2 + 1;
        let rows = self.rows;
        let lanes = threads.clamp(1, rows.max(1));
        ws.ensure(lanes, 0, self.rplan.scratch_len());
        if lanes == 1 {
            let lane = &mut ws.lanes[0];
            for r in 0..rows {
                self.rplan.inverse_with_scratch(
                    &spec[r * h..(r + 1) * h],
                    &mut out[r * last..(r + 1) * last],
                    &mut lane.scratch,
                );
            }
            return;
        }
        let rplan = self.rplan.as_ref();
        let base = rows / lanes;
        let rem = rows % lanes;
        std::thread::scope(|scope| {
            let mut out_rest = out;
            let mut spec_rest = spec;
            for (t, lane) in ws.lanes[..lanes].iter_mut().enumerate() {
                let nrows = base + usize::from(t < rem);
                let (op, or) = std::mem::take(&mut out_rest).split_at_mut(nrows * last);
                let (sp, sr) = spec_rest.split_at(nrows * h);
                out_rest = or;
                spec_rest = sr;
                scope.spawn(move || {
                    for r in 0..nrows {
                        rplan.inverse_with_scratch(
                            &sp[r * h..(r + 1) * h],
                            &mut op[r * last..(r + 1) * last],
                            &mut lane.scratch,
                        );
                    }
                });
            }
        });
    }
}

/// Frequency-domain data of a real field in numpy `rfftn` layout: full
/// resolution along every axis except the last, which keeps only bins
/// `0..=last/2`. The Hermitian extension
/// `X[k] = conj(X[−k mod shape])` recovers the full spectrum.
///
/// This is how [`crate::correction`] stores POCS frequency edits: half the
/// memory of the full vector, expanded on demand at the (cold)
/// quantization and serialization boundaries.
#[derive(Debug, Clone, PartialEq)]
pub struct HalfSpectrum {
    shape: Vec<usize>,
    data: Vec<Complex>,
}

impl HalfSpectrum {
    /// All-zero half spectrum for a real field with `shape`.
    pub fn zeros(shape: &[usize]) -> Self {
        Self {
            shape: shape.to_vec(),
            data: vec![Complex::ZERO; half_len(shape)],
        }
    }

    /// Wrap an existing half-layout buffer (`data.len()` must equal
    /// [`half_len`]`(shape)`).
    pub fn from_parts(shape: &[usize], data: Vec<Complex>) -> Self {
        assert_eq!(data.len(), half_len(shape), "buffer is not half-layout");
        Self {
            shape: shape.to_vec(),
            data,
        }
    }

    /// Keep the half bins of a full-spectrum vector. Exact when `full` is
    /// Hermitian (the spectrum of a real field); otherwise the discarded
    /// redundant bins are simply dropped — use [`HalfSpectrum::fold_full`]
    /// to project instead.
    pub fn from_full(full: &[Complex], shape: &[usize]) -> Self {
        let d = shape.len();
        let last = shape[d - 1];
        let h = last / 2 + 1;
        let rows: usize = shape[..d - 1].iter().product();
        assert_eq!(full.len(), rows * last, "full buffer does not match shape");
        let mut data = Vec::with_capacity(rows * h);
        for r in 0..rows {
            data.extend_from_slice(&full[r * last..r * last + h]);
        }
        Self {
            shape: shape.to_vec(),
            data,
        }
    }

    /// Hermitian projection of an arbitrary full-spectrum vector:
    /// `half[k] = (full[k] + conj(full[−k mod shape])) / 2`. Satisfies
    /// `irfftn(fold_full(F)) == Re(ifftn(F))` exactly (up to rounding) for
    /// every `F`, Hermitian or not. Allocation-free callers fold into an
    /// existing buffer with [`fold_full_into`].
    pub fn fold_full(full: &[Complex], shape: &[usize]) -> Self {
        let mut data = vec![Complex::ZERO; half_len(shape)];
        fold_full_into(full, shape, &mut data);
        Self {
            shape: shape.to_vec(),
            data,
        }
    }

    /// The full logical (real-space) shape.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Half-layout storage (length [`half_len`]`(shape)`).
    pub fn data(&self) -> &[Complex] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [Complex] {
        &mut self.data
    }

    /// Consume into the raw half-layout buffer.
    pub fn into_data(self) -> Vec<Complex> {
        self.data
    }

    /// Number of full-spectrum elements, `prod(shape)`.
    pub fn len_full(&self) -> usize {
        self.shape.iter().product()
    }

    /// Expand to the full Hermitian spectrum vector (length
    /// `prod(shape)`): `full[k] = half[k]` for stored bins,
    /// `conj(half[−k mod shape])` for the rest.
    pub fn expand(&self) -> Vec<Complex> {
        let d = self.shape.len();
        let last = self.shape[d - 1];
        let h = last / 2 + 1;
        let lead = &self.shape[..d - 1];
        let rows: usize = lead.iter().product();
        let mut full = vec![Complex::ZERO; rows * last];
        for_each_row_with_mirror(lead, |r, mr| {
            let hrow = &self.data[r * h..(r + 1) * h];
            let mrow = &self.data[mr * h..(mr + 1) * h];
            let out = &mut full[r * last..(r + 1) * last];
            out[..h].copy_from_slice(hrow);
            for k in h..last {
                out[k] = mrow[last - k].conj();
            }
        });
        full
    }

    /// Number of *full-spectrum* components with a nonzero value: stored
    /// bins whose mirror lives outside the half layout count twice (their
    /// conjugate twin is nonzero iff they are).
    pub fn active_full(&self) -> usize {
        let last = self.shape[self.shape.len() - 1];
        let h = last / 2 + 1;
        let nyq = if last % 2 == 0 { last / 2 } else { usize::MAX };
        let mut count = 0usize;
        for (i, c) in self.data.iter().enumerate() {
            if c.re == 0.0 && c.im == 0.0 {
                continue;
            }
            let k = i % h;
            count += if k == 0 || k == nyq { 1 } else { 2 };
        }
        count
    }
}

/// Visit every lattice point of the row-major `dims` lattice together with
/// its negation mirror: `f(i, mi)` where `mi` is the linear index of
/// `(−idx) mod dims`. An empty `dims` visits the single point `(0, 0)`.
///
/// This is the one shared mixed-radix odometer behind every Hermitian
/// mirror walk in the crate: [`HalfSpectrum::expand`] /
/// [`HalfSpectrum::fold_full`] / [`for_each_full_bin`] pass the *leading*
/// dims (mirroring half-spectrum rows), while the POCS bound-symmetry
/// check passes the **full** shape — the full-lattice variant it needs so
/// asymmetry on the `k_last = 0` / Nyquist planes (whose mates are stored
/// bins themselves) is still caught.
pub fn for_each_row_with_mirror(dims: &[usize], mut f: impl FnMut(usize, usize)) {
    let rows: usize = dims.iter().product();
    let mut idx = vec![0usize; dims.len()];
    for r in 0..rows {
        let mut mr = 0usize;
        for (d, &n) in dims.iter().enumerate() {
            mr = mr * n + ((n - idx[d]) % n);
        }
        f(r, mr);
        for d in (0..dims.len()).rev() {
            idx[d] += 1;
            if idx[d] < dims[d] {
                break;
            }
            idx[d] = 0;
        }
    }
}

/// [`HalfSpectrum::fold_full`] into a caller-provided half-layout buffer
/// (`out.len() ==` [`half_len`]`(shape)`), allocating nothing — the
/// encode-path verifiers fold edit spectra into correction scratch.
pub fn fold_full_into(full: &[Complex], shape: &[usize], out: &mut [Complex]) {
    let d = shape.len();
    assert!(d >= 1, "scalar (0-d) transforms are not supported");
    let last = shape[d - 1];
    let h = last / 2 + 1;
    let lead = &shape[..d - 1];
    let rows: usize = lead.iter().product();
    assert_eq!(full.len(), rows * last, "full buffer does not match shape");
    assert_eq!(out.len(), rows * h, "output is not half-layout");
    for_each_row_with_mirror(lead, |r, mr| {
        for k in 0..h {
            let mirror = full[mr * last + ((last - k) % last)].conj();
            out[r * h + k] = (full[r * last + k] + mirror).scale(0.5);
        }
    });
}

/// Visit every bin of the full spectrum of a real field with `shape`,
/// calling `f(full_idx, half_idx, conjugate)`: the full bin's value is
/// `half[half_idx]`, conjugated when `conjugate` is true. Lets verifiers
/// and bound builders walk the full lattice while reading only the half
/// spectrum.
pub fn for_each_full_bin(shape: &[usize], mut f: impl FnMut(usize, usize, bool)) {
    let d = shape.len();
    assert!(d >= 1, "scalar (0-d) transforms are not supported");
    let last = shape[d - 1];
    let h = last / 2 + 1;
    for_each_row_with_mirror(&shape[..d - 1], |r, mr| {
        let full_base = r * last;
        for k in 0..h {
            f(full_base + k, r * h + k, false);
        }
        for k in h..last {
            f(full_base + k, mr * h + (last - k), true);
        }
    });
}

/// Map a *full-spectrum* bin index to its half-layout storage slot:
/// `Some((half_idx, self_conjugate))` for canonical bins (last-axis
/// frequency `k < last/2 + 1`), `None` for mirror bins, whose value is the
/// conjugate of a canonical bin's. `self_conjugate` is true when the bin
/// is its own Hermitian mirror (`k ∈ {0, Nyquist}` on the last axis and
/// every leading coordinate fixed under negation mod its dim) — the bins
/// whose imaginary part a Hermitian fold zeroes exactly.
///
/// Agrees bin-for-bin with [`for_each_full_bin`] (unit-tested below);
/// sparse consumers — the encode verifier scattering stored edit streams
/// into a half-layout buffer — use this to resolve single bins without
/// walking the whole lattice.
pub fn half_index_of(shape: &[usize], full: usize) -> Option<(usize, bool)> {
    let d = shape.len();
    assert!(d >= 1, "scalar (0-d) transforms are not supported");
    let last = shape[d - 1];
    let h = last / 2 + 1;
    let k = full % last;
    if k >= h {
        return None;
    }
    let row = full / last;
    let k_fixed = k == 0 || (last % 2 == 0 && k == last / 2);
    let mut self_conj = k_fixed;
    if self_conj {
        let mut r = row;
        for &n in shape[..d - 1].iter().rev() {
            let c = r % n;
            r /= n;
            if (n - c) % n != c {
                self_conj = false;
                break;
            }
        }
    }
    Some((row * h + k, self_conj))
}

/// Forward N-D real FFT (out-of-place convenience): real `input` → its
/// [`HalfSpectrum`]. Single-threaded; plan and scratch are built per call.
pub fn rfftn(input: &[f64], shape: &[usize]) -> HalfSpectrum {
    let plan = NdRealFft::new(shape);
    let mut ws = NdFftWorkspace::new();
    let mut data = vec![Complex::ZERO; plan.half_len()];
    plan.forward(input, &mut data, 1, &mut ws);
    HalfSpectrum {
        shape: shape.to_vec(),
        data,
    }
}

/// Inverse N-D real FFT (out-of-place convenience): [`HalfSpectrum`] →
/// real samples, normalized by `1/prod(shape)`.
pub fn irfftn(spec: &HalfSpectrum) -> Vec<f64> {
    let plan = NdRealFft::new(&spec.shape);
    let mut ws = NdFftWorkspace::new();
    let mut data = spec.data.clone();
    let mut out = vec![0.0f64; plan.len_full()];
    plan.inverse(&mut data, &mut out, 1, &mut ws);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fourier::{fftn, ifftn};
    use crate::util::XorShift;

    fn random_real(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = XorShift::new(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    fn shapes() -> Vec<Vec<usize>> {
        vec![
            vec![8],
            vec![9],
            vec![1],
            vec![2],
            vec![6, 8],
            vec![5, 4],
            vec![4, 6, 8],
            vec![3, 5, 7],
            vec![2, 2, 4],
            vec![1, 16],
            vec![16, 1],
            vec![12, 10],
        ]
    }

    #[test]
    fn expand_matches_complex_fftn() {
        for shape in shapes() {
            let n: usize = shape.iter().product();
            let x = random_real(n, 11 + n as u64);
            let buf: Vec<Complex> = x.iter().map(|&v| Complex::new(v, 0.0)).collect();
            let want = fftn(&buf, &shape);
            let got = rfftn(&x, &shape).expand();
            let scale = want.iter().map(|c| c.abs()).fold(1.0f64, f64::max);
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (*a - *b).abs() <= 1e-9 * scale,
                    "shape {shape:?} bin {i}: {a:?} vs {b:?}"
                );
            }
        }
    }

    #[test]
    fn roundtrip_identity() {
        for shape in shapes() {
            let n: usize = shape.iter().product();
            let x = random_real(n, 29 + n as u64);
            let back = irfftn(&rfftn(&x, &shape));
            let scale = x.iter().fold(1.0f64, |a, &v| a.max(v.abs()));
            for (i, (a, b)) in x.iter().zip(&back).enumerate() {
                assert!(
                    (a - b).abs() < 1e-11 * scale,
                    "shape {shape:?} idx {i}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn threaded_output_is_bit_identical() {
        for shape in [vec![16usize, 16], vec![8, 8, 8], vec![4, 100], vec![60]] {
            let n: usize = shape.iter().product();
            let x = random_real(n, 7);
            let plan = NdRealFft::new(&shape);
            let mut base = vec![Complex::ZERO; plan.half_len()];
            let mut ws = NdFftWorkspace::new();
            plan.forward(&x, &mut base, 1, &mut ws);
            for threads in [2usize, 3, 7] {
                let mut spec = vec![Complex::ZERO; plan.half_len()];
                let mut ws_t = NdFftWorkspace::new();
                plan.forward(&x, &mut spec, threads, &mut ws_t);
                assert_eq!(spec, base, "shape {shape:?} threads {threads}");
                let mut out = vec![0.0f64; n];
                plan.inverse(&mut spec, &mut out, threads, &mut ws_t);
                let mut base_out = vec![0.0f64; n];
                let mut base_spec = base.clone();
                plan.inverse(&mut base_spec, &mut base_out, 1, &mut ws);
                assert_eq!(out, base_out, "shape {shape:?} threads {threads}");
            }
        }
    }

    /// Reduced-shape sweep sized for the Miri interpreter: drives both
    /// `run_line_item` paths (contiguous lines and the strided
    /// gather/scatter) single- and multi-threaded. The CI Miri job runs
    /// exactly this test; full-size coverage lives in
    /// `threaded_output_is_bit_identical`.
    #[test]
    fn miri_reduced_shapes_exercise_unsafe_paths() {
        for shape in [vec![4usize, 6], vec![3, 4, 2], vec![8]] {
            let n: usize = shape.iter().product();
            let x = random_real(n, 3);
            let plan = NdRealFft::new(&shape);
            let mut base = vec![Complex::ZERO; plan.half_len()];
            let mut ws = NdFftWorkspace::new();
            plan.forward(&x, &mut base, 1, &mut ws);
            let scale = x.iter().fold(1.0f64, |a, &v| a.max(v.abs()));
            for threads in [1usize, 2] {
                let mut spec = vec![Complex::ZERO; plan.half_len()];
                let mut ws_t = NdFftWorkspace::new();
                plan.forward(&x, &mut spec, threads, &mut ws_t);
                assert_eq!(spec, base, "shape {shape:?} threads {threads}");
                let mut out = vec![0.0f64; n];
                plan.inverse(&mut spec, &mut out, threads, &mut ws_t);
                for (a, b) in x.iter().zip(&out) {
                    assert!((a - b).abs() < 1e-11 * scale, "shape {shape:?}");
                }
            }
        }
    }

    #[test]
    fn workspace_is_stable_across_iterations() {
        // Steady-state POCS iterations must not grow the workspace: after
        // the first forward/inverse pair, allocated capacity is fixed.
        let shape = [12usize, 10, 9]; // odd last axis exercises Bluestein
        let n: usize = shape.iter().product();
        let x = random_real(n, 5);
        let plan = NdRealFft::new(&shape);
        let mut ws = NdFftWorkspace::new();
        let mut spec = vec![Complex::ZERO; plan.half_len()];
        let mut out = vec![0.0f64; n];
        plan.forward(&x, &mut spec, 2, &mut ws);
        plan.inverse(&mut spec, &mut out, 2, &mut ws);
        let warm = ws.allocated_elems();
        let warm_events = ws.grow_events();
        assert!(warm > 0);
        assert!(warm_events > 0);
        for _ in 0..3 {
            plan.forward(&x, &mut spec, 2, &mut ws);
            plan.inverse(&mut spec, &mut out, 2, &mut ws);
        }
        assert_eq!(ws.allocated_elems(), warm, "workspace grew in steady state");
        assert_eq!(
            ws.grow_events(),
            warm_events,
            "workspace recorded growth events in steady state"
        );
    }

    #[test]
    fn fold_full_matches_real_part_of_ifftn() {
        // irfftn(fold_full(F)) == Re(ifftn(F)) for arbitrary, non-Hermitian F.
        let mut rng = XorShift::new(88);
        for shape in [vec![8usize], vec![9], vec![6, 8], vec![3, 4, 5]] {
            let n: usize = shape.iter().product();
            let full: Vec<Complex> = (0..n)
                .map(|_| Complex::new(rng.normal(), rng.normal()))
                .collect();
            let want: Vec<f64> = ifftn(&full, &shape).iter().map(|c| c.re).collect();
            let got = irfftn(&HalfSpectrum::fold_full(&full, &shape));
            let scale = want.iter().fold(1.0f64, |a, &v| a.max(v.abs()));
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (a - b).abs() < 1e-11 * scale,
                    "shape {shape:?} idx {i}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn from_full_and_expand_are_inverse_on_hermitian_input() {
        let shape = [6usize, 8];
        let x = random_real(48, 3);
        let half = rfftn(&x, &shape);
        let full = half.expand();
        let back = HalfSpectrum::from_full(&full, &shape);
        assert_eq!(back, half);
    }

    #[test]
    fn active_full_counts_hermitian_pairs() {
        // 1-D n=8: bins 1..=3 are paired, 0 and 4 self-conjugate.
        let mut hs = HalfSpectrum::zeros(&[8]);
        hs.data_mut()[0] = Complex::ONE; // DC: 1
        hs.data_mut()[2] = Complex::I; // paired: 2
        hs.data_mut()[4] = Complex::ONE; // Nyquist: 1
        assert_eq!(hs.active_full(), 4);
        // Odd n=9: only bin 0 is self-conjugate.
        let mut hs = HalfSpectrum::zeros(&[9]);
        hs.data_mut()[4] = Complex::ONE; // paired: 2
        assert_eq!(hs.active_full(), 2);
    }

    #[test]
    fn row_mirror_walk_matches_explicit_negation() {
        // The shared odometer visits every point once, in row-major order,
        // with the mirror of the mirror landing back on the point.
        for dims in [vec![], vec![8usize], vec![9], vec![4, 6], vec![3, 4, 5]] {
            let rows: usize = dims.iter().product();
            let mut seen = vec![false; rows];
            let mut expect_r = 0usize;
            for_each_row_with_mirror(&dims, |r, mr| {
                assert_eq!(r, expect_r, "dims {dims:?}: not row-major order");
                expect_r += 1;
                assert!(mr < rows.max(1), "dims {dims:?}: mirror out of range");
                assert!(!seen[r], "dims {dims:?}: row {r} visited twice");
                seen[r] = true;
                // Explicit negation: decompose r, negate per axis, rebuild.
                let mut rest = r;
                let mut coords = vec![0usize; dims.len()];
                for d in (0..dims.len()).rev() {
                    coords[d] = rest % dims[d];
                    rest /= dims[d];
                }
                let mut want = 0usize;
                for (d, &n) in dims.iter().enumerate() {
                    want = want * n + ((n - coords[d]) % n);
                }
                assert_eq!(mr, want, "dims {dims:?} row {r}");
            });
            assert_eq!(expect_r, rows.max(1));
        }
        // The mirror is an involution.
        let dims = [3usize, 4, 5];
        let mut mirror = vec![0usize; 60];
        for_each_row_with_mirror(&dims, |r, mr| mirror[r] = mr);
        for r in 0..60 {
            assert_eq!(mirror[mirror[r]], r, "mirror not involutive at {r}");
        }
    }

    #[test]
    fn fold_full_into_matches_allocating_fold() {
        let mut rng = XorShift::new(44);
        for shape in [vec![8usize], vec![9], vec![6, 8], vec![3, 4, 5]] {
            let n: usize = shape.iter().product();
            let full: Vec<Complex> = (0..n)
                .map(|_| Complex::new(rng.normal(), rng.normal()))
                .collect();
            let want = HalfSpectrum::fold_full(&full, &shape);
            // Dirty output buffer must not leak through.
            let mut out = vec![Complex::new(9.0, -9.0); half_len(&shape)];
            fold_full_into(&full, &shape, &mut out);
            assert_eq!(out, want.data(), "shape {shape:?}");
        }
    }

    #[test]
    fn line_block_shrinks_for_long_lines() {
        assert_eq!(line_block(8), LINE_BLOCK);
        assert_eq!(line_block(2048), LINE_BLOCK);
        assert_eq!(line_block(4096), 4);
        assert_eq!(line_block(8192), 2);
        assert_eq!(line_block(65536), 2);
        // The shrink tiers keep the staged block within the ~256 KiB
        // budget up to 8192-point axes (16 B per complex element); beyond
        // that the 2-line floor holds batching without a budget claim.
        for len in [1usize, 64, 2048, 2049, 4096, 4097, 8192] {
            let b = line_block(len);
            assert!((2..=LINE_BLOCK).contains(&b));
            assert!(b * len * 16 <= 256 * 1024, "len {len}: {} B staged", b * len * 16);
        }
        assert_eq!(line_block(1 << 20), 2);
    }

    #[test]
    fn ndrplan_cache_returns_shared_handles() {
        let a = ndrplan_for(&[6, 8]);
        let b = ndrplan_for(&[6, 8]);
        assert!(Arc::ptr_eq(&a, &b), "same shape must share one plan");
        assert_eq!(a.shape(), &[6, 8]);
        let c = ndrplan_for(&[8, 6]);
        assert!(!Arc::ptr_eq(&a, &c), "distinct shapes get distinct plans");
    }

    #[test]
    fn for_each_full_bin_covers_the_lattice_once() {
        for shape in [vec![8usize], vec![9], vec![4, 6], vec![3, 4, 5]] {
            let n: usize = shape.iter().product();
            let mut seen = vec![0usize; n];
            let h_total = half_len(&shape);
            for_each_full_bin(&shape, |full, half, _conj| {
                assert!(half < h_total);
                seen[full] += 1;
            });
            assert!(seen.iter().all(|&c| c == 1), "shape {shape:?}: {seen:?}");
        }
    }

    #[test]
    fn half_index_of_agrees_with_full_bin_walk() {
        for shape in [vec![8usize], vec![9], vec![1], vec![2], vec![4, 6], vec![3, 4, 5]] {
            let n: usize = shape.iter().product();
            // Full-lattice mirror map (negation mod dims over the whole
            // shape — the same odometer the symmetry checker uses).
            let mut mirror = vec![0usize; n];
            for_each_row_with_mirror(&shape, |i, mi| mirror[i] = mi);
            let mut canonical = 0usize;
            for_each_full_bin(&shape, |full, half, conj| {
                match half_index_of(&shape, full) {
                    Some((got_half, self_conj)) => {
                        assert!(!conj, "shape {shape:?} bin {full}: mirror marked canonical");
                        assert_eq!(got_half, half, "shape {shape:?} bin {full}");
                        assert_eq!(
                            self_conj,
                            mirror[full] == full,
                            "shape {shape:?} bin {full}"
                        );
                        canonical += 1;
                    }
                    None => {
                        assert!(conj, "shape {shape:?} bin {full}: canonical marked mirror");
                    }
                }
            });
            assert_eq!(canonical, half_len(&shape), "shape {shape:?}");
        }
    }

    #[test]
    fn for_each_full_bin_values_match_fftn() {
        let shape = [4usize, 6];
        let x = random_real(24, 17);
        let half = rfftn(&x, &shape);
        let buf: Vec<Complex> = x.iter().map(|&v| Complex::new(v, 0.0)).collect();
        let full = fftn(&buf, &shape);
        let scale = full.iter().map(|c| c.abs()).fold(1.0f64, f64::max);
        for_each_full_bin(&shape, |fi, hi, conj| {
            let v = if conj {
                half.data()[hi].conj()
            } else {
                half.data()[hi]
            };
            assert!(
                (v - full[fi]).abs() < 1e-10 * scale,
                "full {fi} half {hi} conj {conj}: {v:?} vs {:?}",
                full[fi]
            );
        });
    }
}
