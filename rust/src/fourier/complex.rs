//! Minimal `f64` complex number (the offline crate set has no `num-complex`).

use std::ops::{Add, AddAssign, Div, Mul, MulAssign, Neg, Sub, SubAssign};

/// A complex number with `f64` components.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Complex {
    pub re: f64,
    pub im: f64,
}

impl Complex {
    pub const ZERO: Complex = Complex { re: 0.0, im: 0.0 };
    pub const ONE: Complex = Complex { re: 1.0, im: 0.0 };
    pub const I: Complex = Complex { re: 0.0, im: 1.0 };

    #[inline]
    pub fn new(re: f64, im: f64) -> Self {
        Self { re, im }
    }

    /// `e^{iθ}`.
    #[inline]
    pub fn from_angle(theta: f64) -> Self {
        let (s, c) = theta.sin_cos();
        Self { re: c, im: s }
    }

    #[inline]
    pub fn conj(self) -> Self {
        Self {
            re: self.re,
            im: -self.im,
        }
    }

    /// Squared magnitude `|z|²`.
    #[inline]
    pub fn norm_sqr(self) -> f64 {
        self.re * self.re + self.im * self.im
    }

    /// Magnitude `|z|`.
    #[inline]
    pub fn abs(self) -> f64 {
        self.norm_sqr().sqrt()
    }

    /// Scale by a real factor.
    #[inline]
    pub fn scale(self, s: f64) -> Self {
        Self {
            re: self.re * s,
            im: self.im * s,
        }
    }

    /// Max of |re|, |im| — the ∞-norm the f-cube constraint uses.
    #[inline]
    pub fn linf(self) -> f64 {
        self.re.abs().max(self.im.abs())
    }
}

impl Add for Complex {
    type Output = Complex;
    #[inline]
    fn add(self, o: Complex) -> Complex {
        Complex::new(self.re + o.re, self.im + o.im)
    }
}

impl Sub for Complex {
    type Output = Complex;
    #[inline]
    fn sub(self, o: Complex) -> Complex {
        Complex::new(self.re - o.re, self.im - o.im)
    }
}

impl Mul for Complex {
    type Output = Complex;
    #[inline]
    fn mul(self, o: Complex) -> Complex {
        Complex::new(
            self.re * o.re - self.im * o.im,
            self.re * o.im + self.im * o.re,
        )
    }
}

impl Div for Complex {
    type Output = Complex;
    #[inline]
    fn div(self, o: Complex) -> Complex {
        let d = o.norm_sqr();
        Complex::new(
            (self.re * o.re + self.im * o.im) / d,
            (self.im * o.re - self.re * o.im) / d,
        )
    }
}

impl Neg for Complex {
    type Output = Complex;
    #[inline]
    fn neg(self) -> Complex {
        Complex::new(-self.re, -self.im)
    }
}

impl AddAssign for Complex {
    #[inline]
    fn add_assign(&mut self, o: Complex) {
        self.re += o.re;
        self.im += o.im;
    }
}

impl SubAssign for Complex {
    #[inline]
    fn sub_assign(&mut self, o: Complex) {
        self.re -= o.re;
        self.im -= o.im;
    }
}

impl MulAssign for Complex {
    #[inline]
    fn mul_assign(&mut self, o: Complex) {
        *self = *self * o;
    }
}

impl From<f64> for Complex {
    #[inline]
    fn from(re: f64) -> Self {
        Complex::new(re, 0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic_identities() {
        let a = Complex::new(1.0, 2.0);
        let b = Complex::new(-3.0, 0.5);
        assert_eq!(a + b, Complex::new(-2.0, 2.5));
        assert_eq!(a - b, Complex::new(4.0, 1.5));
        // (1+2i)(-3+0.5i) = -3 + 0.5i - 6i + i² = -4 - 5.5i
        assert_eq!(a * b, Complex::new(-4.0, -5.5));
        let q = (a * b) / b;
        assert!((q.re - a.re).abs() < 1e-12 && (q.im - a.im).abs() < 1e-12);
    }

    #[test]
    fn euler_identity() {
        let z = Complex::from_angle(std::f64::consts::PI);
        assert!((z.re + 1.0).abs() < 1e-15 && z.im.abs() < 1e-12);
    }

    #[test]
    fn norms() {
        let z = Complex::new(3.0, -4.0);
        assert_eq!(z.norm_sqr(), 25.0);
        assert_eq!(z.abs(), 5.0);
        assert_eq!(z.linf(), 4.0);
        assert_eq!(z.conj(), Complex::new(3.0, 4.0));
    }
}
