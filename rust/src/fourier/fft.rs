//! 1-D fast Fourier transforms.
//!
//! * Power-of-two sizes use an iterative in-place split-radix-family
//!   kernel: radix-4 butterflies with a radix-2 first stage when log₂n is
//!   odd, on a precomputed bit-reversal permutation with per-stage twiddle
//!   tables. Radix-4 needs 3 complex multiplies per 4 outputs where
//!   radix-2 needs 4, and halves the number of full passes over the data —
//!   the ~25–33% multiply saving the FFT literature attributes to the
//!   split-radix family. The plain radix-2 kernel is kept as the
//!   equivalence oracle ([`Fft::process_with_scratch_radix2`]), used by the
//!   property tests and the kernel benchmark baseline.
//! * Arbitrary sizes fall back to Bluestein's algorithm (chirp-z), which
//!   reduces an N-point DFT to a power-of-two cyclic convolution.
//!
//! A [`Fft`] instance is a *plan*: it caches the permutation, twiddles, and
//! (for Bluestein) the pre-transformed chirp, so repeated transforms of the
//! same size — the common case in the POCS loop — pay no setup cost.

use std::f64::consts::PI;

use super::Complex;

/// Transform direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FftDirection {
    Forward,
    Inverse,
}

/// Which pow-2 butterfly kernel to run (the plan data is shared).
#[derive(Clone, Copy, PartialEq, Eq)]
enum Kernel {
    /// Production kernel: radix-4 stages, radix-2 finish for odd log₂n.
    Radix4,
    /// Equivalence oracle: plain iterative radix-2.
    Radix2,
}

/// A planned 1-D FFT of fixed size.
///
/// Normalization follows the numpy convention: `Forward` is unnormalized,
/// `Inverse` scales by `1/N`, so `ifft(fft(x)) == x`.
pub struct Fft {
    n: usize,
    kind: Kind,
}

enum Kind {
    /// Power of two: bit-reversal permutation + twiddle table
    /// `w^j = e^{-2πi j / n}` for `j in 0..3n/4` (radix-2 reads `< n/2`,
    /// the radix-4 stages read `w^{3k}` up to `< 3n/4`).
    Pow2 {
        rev: Vec<u32>,
        twiddles: Vec<Complex>,
    },
    /// Bluestein chirp-z: pad to power-of-two m ≥ 2n-1.
    Bluestein {
        m: usize,
        inner: Box<Fft>,
        /// a_k = e^{-iπ k²/n} (forward chirp), length n.
        chirp: Vec<Complex>,
        /// FFT of the zero-padded conjugate chirp kernel, length m.
        kernel_fft: Vec<Complex>,
    },
}

// `len` has no `is_empty` companion on purpose: the constructor asserts
// `n ≥ 1`, so a plan can never be empty.
#[allow(clippy::len_without_is_empty)]
impl Fft {
    /// Plan a transform of size `n` (n ≥ 1).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "FFT size must be ≥ 1");
        if n.is_power_of_two() {
            let rev = bit_reversal(n);
            // 3n/4 entries: enough for the radix-4 stages' w^{3k} factors
            // (and a superset of the n/2 the radix-2 oracle reads).
            let mut twiddles = Vec::with_capacity(3 * n / 4);
            for j in 0..3 * n / 4 {
                twiddles.push(Complex::from_angle(-2.0 * PI * j as f64 / n as f64));
            }
            Fft {
                n,
                kind: Kind::Pow2 { rev, twiddles },
            }
        } else {
            // Bluestein: x_k · a_k convolved with b; b_j = e^{iπ j²/n}.
            let m = (2 * n - 1).next_power_of_two();
            let inner = Box::new(Fft::new(m));
            let mut chirp = Vec::with_capacity(n);
            for k in 0..n {
                // k² mod 2n avoids catastrophic angle growth for large k.
                let k2 = ((k as u128 * k as u128) % (2 * n as u128)) as f64;
                chirp.push(Complex::from_angle(-PI * k2 / n as f64));
            }
            let mut kernel = vec![Complex::ZERO; m];
            for j in 0..n {
                let b = chirp[j].conj();
                kernel[j] = b;
                if j != 0 {
                    kernel[m - j] = b;
                }
            }
            inner.forward_inplace_pow2(&mut kernel, Kernel::Radix4);
            Fft {
                n,
                kind: Kind::Bluestein {
                    m,
                    inner,
                    chirp,
                    kernel_fft: kernel,
                },
            }
        }
    }

    /// Transform size.
    pub fn len(&self) -> usize {
        self.n
    }

    /// Scratch elements required by [`Fft::process_with_scratch`]: zero for
    /// pow-2 plans, the padded convolution length `m` for Bluestein.
    pub fn scratch_len(&self) -> usize {
        match &self.kind {
            Kind::Pow2 { .. } => 0,
            Kind::Bluestein { m, .. } => *m,
        }
    }

    /// Approximate resident bytes of this plan's tables (permutation,
    /// twiddles, Bluestein chirp + kernel, recursively) — the unit of
    /// account for the plan-cache byte budget.
    pub fn approx_bytes(&self) -> usize {
        let own = std::mem::size_of::<Self>();
        own + match &self.kind {
            Kind::Pow2 { rev, twiddles } => {
                rev.len() * std::mem::size_of::<u32>()
                    + twiddles.len() * std::mem::size_of::<Complex>()
            }
            Kind::Bluestein {
                inner,
                chirp,
                kernel_fft,
                ..
            } => {
                inner.approx_bytes()
                    + (chirp.len() + kernel_fft.len()) * std::mem::size_of::<Complex>()
            }
        }
    }

    /// In-place transform of a buffer of length `n`. Allocates Bluestein
    /// scratch internally; steady-state callers (the POCS loop, the N-D
    /// axis sweeps) should use [`Fft::process_with_scratch`] instead.
    pub fn process(&self, data: &mut [Complex], dir: FftDirection) {
        let mut scratch = vec![Complex::ZERO; self.scratch_len()];
        self.process_with_scratch(data, dir, &mut scratch);
    }

    /// In-place transform with caller-provided scratch (`scratch.len() ≥`
    /// [`Fft::scratch_len`]); allocates nothing. Scratch contents on entry
    /// are irrelevant and unspecified on exit.
    pub fn process_with_scratch(
        &self,
        data: &mut [Complex],
        dir: FftDirection,
        scratch: &mut [Complex],
    ) {
        self.process_inner(data, dir, scratch, Kernel::Radix4);
    }

    /// [`Fft::process_with_scratch`] through the plain radix-2 butterfly
    /// kernel — the equivalence *oracle* for the production radix-4 path
    /// (property-tested to agree at rounding level) and the baseline the
    /// kernel benchmark measures the split-radix speedup against. Same
    /// plan, same scratch contract; only the butterfly schedule differs,
    /// so results agree to FFT rounding (not bit-exactly — the summation
    /// order differs).
    pub fn process_with_scratch_radix2(
        &self,
        data: &mut [Complex],
        dir: FftDirection,
        scratch: &mut [Complex],
    ) {
        self.process_inner(data, dir, scratch, Kernel::Radix2);
    }

    fn process_inner(
        &self,
        data: &mut [Complex],
        dir: FftDirection,
        scratch: &mut [Complex],
        kernel: Kernel,
    ) {
        assert_eq!(data.len(), self.n, "buffer length != plan size");
        assert!(
            scratch.len() >= self.scratch_len(),
            "scratch {} < required {}",
            scratch.len(),
            self.scratch_len()
        );
        if self.n == 1 {
            return;
        }
        match dir {
            FftDirection::Forward => self.forward(data, scratch, kernel),
            FftDirection::Inverse => {
                // ifft(x) = conj(fft(conj(x))) / n
                for v in data.iter_mut() {
                    *v = v.conj();
                }
                self.forward(data, scratch, kernel);
                let s = 1.0 / self.n as f64;
                for v in data.iter_mut() {
                    *v = v.conj().scale(s);
                }
            }
        }
    }

    /// Out-of-place convenience wrapper.
    pub fn transform(&self, input: &[Complex], dir: FftDirection) -> Vec<Complex> {
        let mut buf = input.to_vec();
        self.process(&mut buf, dir);
        buf
    }

    fn forward(&self, data: &mut [Complex], scratch: &mut [Complex], kernel: Kernel) {
        match &self.kind {
            Kind::Pow2 { .. } => self.forward_inplace_pow2(data, kernel),
            Kind::Bluestein {
                m,
                inner,
                chirp,
                kernel_fft,
            } => {
                let n = self.n;
                // The padded chirp product lives in caller scratch — no
                // per-call allocation in the convolution.
                let a = &mut scratch[..*m];
                for k in 0..n {
                    a[k] = data[k] * chirp[k];
                }
                for v in a[n..].iter_mut() {
                    *v = Complex::ZERO;
                }
                inner.forward_inplace_pow2(a, kernel);
                for (x, k) in a.iter_mut().zip(kernel_fft.iter()) {
                    *x = *x * *k;
                }
                // Inverse inner transform via conjugation.
                for v in a.iter_mut() {
                    *v = v.conj();
                }
                inner.forward_inplace_pow2(a, kernel);
                let s = 1.0 / *m as f64;
                for (k, out) in data.iter_mut().enumerate() {
                    *out = a[k].conj().scale(s) * chirp[k];
                }
            }
        }
    }

    /// The pow-2 kernel dispatcher (only valid when `kind` is `Pow2`).
    fn forward_inplace_pow2(&self, data: &mut [Complex], kernel: Kernel) {
        match kernel {
            Kernel::Radix4 => self.forward_inplace_radix4(data),
            Kernel::Radix2 => self.forward_inplace_radix2(data),
        }
    }

    /// Production pow-2 kernel: DIT radix-4 stages after the shared
    /// bit-reversal permutation, with one twiddle-free radix-2 stage first
    /// when log₂n is odd. On base-2 bit-reversed input the four quarter
    /// sub-transforms of a size-`4q` block sit in memory order
    /// residue-0, residue-**2**, residue-**1**, residue-3 (reversing the
    /// two low bits swaps residues 1 and 2), so the middle two blocks are
    /// read swapped — the standard trick that lets radix-4 run on the
    /// radix-2 permutation the oracle shares.
    fn forward_inplace_radix4(&self, data: &mut [Complex]) {
        let (rev, twiddles) = match &self.kind {
            Kind::Pow2 { rev, twiddles } => (rev, twiddles),
            _ => unreachable!("pow-2 kernel called on non-pow2 plan"),
        };
        let n = data.len();
        // Bit-reversal permutation.
        for i in 0..n {
            let j = rev[i] as usize;
            if i < j {
                data.swap(i, j);
            }
        }
        let mut half = 1;
        if n.trailing_zeros() % 2 == 1 {
            // Odd log₂n: one radix-2 stage over adjacent pairs (twiddle =
            // 1, so no multiplies), leaving a power-of-4 ladder above.
            let mut j = 0;
            while j < n {
                let u = data[j];
                let v = data[j + 1];
                data[j] = u + v;
                data[j + 1] = u - v;
                j += 2;
            }
            half = 2;
        }
        // Radix-4 stages: combine four size-q blocks into one size-4q DFT.
        //   t0 = A[k], t1 = w^k B[k], t2 = w^{2k} C[k], t3 = w^{3k} D[k]
        //   X[k]    = (t0+t2) + (t1+t3)      X[k+2q] = (t0+t2) − (t1+t3)
        //   X[k+q]  = (t0−t2) − i(t1−t3)     X[k+3q] = (t0−t2) + i(t1−t3)
        // with B at offset 2q and C at offset q (see the method docs).
        while half < n {
            let q = half;
            let l = 4 * q;
            let stride = n / l;
            let mut base = 0;
            while base < n {
                for k in 0..q {
                    let w1 = twiddles[k * stride];
                    let w2 = twiddles[2 * k * stride];
                    let w3 = twiddles[3 * k * stride];
                    let t0 = data[base + k];
                    let t2 = data[base + k + q] * w2;
                    let t1 = data[base + k + 2 * q] * w1;
                    let t3 = data[base + k + 3 * q] * w3;
                    let s0 = t0 + t2;
                    let d0 = t0 - t2;
                    let s1 = t1 + t3;
                    let d1 = t1 - t3;
                    // −i·d1 rotates the odd-half difference.
                    let md1 = Complex::new(d1.im, -d1.re);
                    data[base + k] = s0 + s1;
                    data[base + k + q] = d0 + md1;
                    data[base + k + 2 * q] = s0 - s1;
                    data[base + k + 3 * q] = d0 - md1;
                }
                base += l;
            }
            half = l;
        }
    }

    /// The radix-2 oracle kernel (only valid when `kind` is `Pow2`).
    fn forward_inplace_radix2(&self, data: &mut [Complex]) {
        let (rev, twiddles) = match &self.kind {
            Kind::Pow2 { rev, twiddles } => (rev, twiddles),
            _ => unreachable!("pow-2 kernel called on non-pow2 plan"),
        };
        let n = data.len();
        // Bit-reversal permutation.
        for i in 0..n {
            let j = rev[i] as usize;
            if i < j {
                data.swap(i, j);
            }
        }
        // Iterative butterflies. Stage with half-size `half` uses twiddle
        // stride n / (2*half). (A specialized-first-stages variant was
        // measured 15% *slower* — see EXPERIMENTS.md §Perf — so the
        // uniform loop stays; the production speedup comes from the
        // radix-4 kernel above instead.)
        let mut half = 1;
        while half < n {
            let stride = n / (2 * half);
            let mut base = 0;
            while base < n {
                let mut tw = 0;
                for j in base..base + half {
                    let w = twiddles[tw];
                    let u = data[j];
                    let v = data[j + half] * w;
                    data[j] = u + v;
                    data[j + half] = u - v;
                    tw += stride;
                }
                base += 2 * half;
            }
            half *= 2;
        }
    }
}

/// Bit-reversal table for size n (power of two).
fn bit_reversal(n: usize) -> Vec<u32> {
    let bits = n.trailing_zeros();
    if bits == 0 {
        return vec![0];
    }
    let mut rev = vec![0u32; n];
    for i in 0..n {
        rev[i] = (i as u32).reverse_bits() >> (32 - bits);
    }
    rev
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fourier::dft_naive;
    use crate::util::XorShift;

    fn random_signal(n: usize, seed: u64) -> Vec<Complex> {
        let mut rng = XorShift::new(seed);
        (0..n)
            .map(|_| Complex::new(rng.normal(), rng.normal()))
            .collect()
    }

    fn assert_close(a: &[Complex], b: &[Complex], tol: f64) {
        assert_eq!(a.len(), b.len());
        let scale = b.iter().map(|c| c.abs()).fold(1.0_f64, f64::max);
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            let d = (*x - *y).abs();
            assert!(d <= tol * scale, "idx {i}: {x:?} vs {y:?} (|d|={d:.3e})");
        }
    }

    #[test]
    fn matches_naive_dft_pow2() {
        for &n in &[1usize, 2, 4, 8, 64, 256] {
            let x = random_signal(n, n as u64);
            let plan = Fft::new(n);
            let fast = plan.transform(&x, FftDirection::Forward);
            let slow = dft_naive(&x);
            assert_close(&fast, &slow, 1e-10);
        }
    }

    #[test]
    fn matches_naive_dft_non_pow2() {
        for &n in &[3usize, 5, 6, 7, 12, 100, 31, 243] {
            let x = random_signal(n, n as u64 + 1);
            let plan = Fft::new(n);
            let fast = plan.transform(&x, FftDirection::Forward);
            let slow = dft_naive(&x);
            assert_close(&fast, &slow, 1e-9);
        }
    }

    /// The production radix-4 kernel and the radix-2 oracle agree at FFT
    /// rounding level across every pow-2 size — including n = 2 (pure
    /// radix-2 finish stage) and both parities of log₂n — in both
    /// directions, and through the Bluestein convolution that runs its
    /// inner pow-2 transforms with whichever kernel is selected.
    #[test]
    fn radix4_matches_radix2_oracle_all_pow2() {
        for &n in &[2usize, 4, 8, 16, 32, 64, 128, 256, 1024, 4096] {
            let x = random_signal(n, 7000 + n as u64);
            let plan = Fft::new(n);
            let mut scratch = vec![Complex::ZERO; plan.scratch_len()];
            for dir in [FftDirection::Forward, FftDirection::Inverse] {
                let mut fast = x.clone();
                plan.process_with_scratch(&mut fast, dir, &mut scratch);
                let mut oracle = x.clone();
                plan.process_with_scratch_radix2(&mut oracle, dir, &mut scratch);
                assert_close(&fast, &oracle, 1e-12);
            }
        }
        // Bluestein sizes: both kernels drive the inner convolution.
        for &n in &[7usize, 100, 509] {
            let x = random_signal(n, 9000 + n as u64);
            let plan = Fft::new(n);
            let mut scratch = vec![Complex::ZERO; plan.scratch_len()];
            let mut fast = x.clone();
            plan.process_with_scratch(&mut fast, FftDirection::Forward, &mut scratch);
            let mut oracle = x.clone();
            plan.process_with_scratch_radix2(&mut oracle, FftDirection::Forward, &mut scratch);
            assert_close(&fast, &oracle, 1e-11);
        }
    }

    #[test]
    fn roundtrip_identity() {
        for &n in &[8usize, 10, 17, 128, 1000] {
            let x = random_signal(n, 99 + n as u64);
            let plan = Fft::new(n);
            let y = plan.transform(&x, FftDirection::Forward);
            let z = plan.transform(&y, FftDirection::Inverse);
            assert_close(&z, &x, 1e-11);
        }
    }

    #[test]
    fn parseval_energy_conserved() {
        let n = 512;
        let x = random_signal(n, 5);
        let plan = Fft::new(n);
        let y = plan.transform(&x, FftDirection::Forward);
        let ex: f64 = x.iter().map(|c| c.norm_sqr()).sum();
        let ey: f64 = y.iter().map(|c| c.norm_sqr()).sum::<f64>() / n as f64;
        assert!((ex - ey).abs() < 1e-8 * ex);
    }

    #[test]
    fn pure_tone_lands_in_single_bin() {
        let n = 64;
        let k0 = 5;
        let x: Vec<Complex> = (0..n)
            .map(|i| Complex::from_angle(2.0 * PI * k0 as f64 * i as f64 / n as f64))
            .collect();
        let y = Fft::new(n).transform(&x, FftDirection::Forward);
        for (k, c) in y.iter().enumerate() {
            if k == k0 {
                assert!((c.re - n as f64).abs() < 1e-9);
            } else {
                assert!(c.abs() < 1e-9, "leakage at {k}: {c:?}");
            }
        }
    }

    #[test]
    fn hermitian_symmetry_for_real_input() {
        let n = 48;
        let mut rng = XorShift::new(3);
        let x: Vec<Complex> = (0..n).map(|_| Complex::new(rng.normal(), 0.0)).collect();
        let y = Fft::new(n).transform(&x, FftDirection::Forward);
        for k in 1..n {
            let d = y[n - k] - y[k].conj();
            assert!(d.abs() < 1e-9, "X[N-k] != conj(X[k]) at {k}");
        }
    }

    #[test]
    fn linearity() {
        let n = 40;
        let a = random_signal(n, 1);
        let b = random_signal(n, 2);
        let plan = Fft::new(n);
        let fa = plan.transform(&a, FftDirection::Forward);
        let fb = plan.transform(&b, FftDirection::Forward);
        let sum: Vec<Complex> = a.iter().zip(&b).map(|(x, y)| *x + *y).collect();
        let fsum = plan.transform(&sum, FftDirection::Forward);
        let expect: Vec<Complex> = fa.iter().zip(&fb).map(|(x, y)| *x + *y).collect();
        assert_close(&fsum, &expect, 1e-10);
    }

    #[test]
    fn scratch_path_matches_allocating_path() {
        // Bluestein via explicit scratch must be bit-identical to the
        // allocating wrapper (same kernel, different storage), and the
        // scratch contents on entry must not matter.
        for &n in &[7usize, 100, 509, 128] {
            let x = random_signal(n, 42 + n as u64);
            let plan = Fft::new(n);
            let mut a = x.clone();
            plan.process(&mut a, FftDirection::Forward);
            let mut b = x.clone();
            let mut scratch = vec![Complex::new(3.25, -7.5); plan.scratch_len()];
            plan.process_with_scratch(&mut b, FftDirection::Forward, &mut scratch);
            assert_eq!(a, b, "n={n}");
            // Round-trip through the scratch path too.
            plan.process_with_scratch(&mut b, FftDirection::Inverse, &mut scratch);
            assert_close(&b, &x, 1e-10);
        }
    }

    #[test]
    fn large_bluestein_prime() {
        // 509 is prime; exercises the chirp path end-to-end.
        let n = 509;
        let x = random_signal(n, 11);
        let plan = Fft::new(n);
        let y = plan.transform(&x, FftDirection::Forward);
        let z = plan.transform(&y, FftDirection::Inverse);
        assert_close(&z, &x, 1e-9);
    }
}
