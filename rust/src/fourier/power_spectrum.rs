//! Radially-binned power spectrum (paper §III).
//!
//! Pipeline, exactly as the paper describes for the Nyx analysis:
//! 1. normalize fluctuations: `x' = (x - x̄) / x̄`;
//! 2. FFT to the frequency domain;
//! 3. accumulate `|X'_k|²` over shells of constant integer radius
//!    `k = round(√(u² + v² + w²))` using *signed* frequency indices.

use crate::data::Field;

use super::{fftn, rfftn, signed_freq, Complex};

/// A binned power spectrum: `power[k]` is `P(k)` for wavenumber `k`,
/// `count[k]` the number of Fourier modes in the shell.
#[derive(Debug, Clone)]
pub struct PowerSpectrum {
    pub power: Vec<f64>,
    pub count: Vec<usize>,
}

impl PowerSpectrum {
    /// Number of wavenumber bins.
    pub fn len(&self) -> usize {
        self.power.len()
    }

    pub fn is_empty(&self) -> bool {
        self.power.is_empty()
    }

    /// Elementwise relative error against a reference spectrum:
    /// `(P̂(k) − P(k)) / P(k)`, NaN where the reference is 0.
    pub fn relative_error(&self, reference: &PowerSpectrum) -> Vec<f64> {
        self.power
            .iter()
            .zip(&reference.power)
            .map(|(p_hat, p)| {
                if *p == 0.0 {
                    f64::NAN
                } else {
                    (p_hat - p) / p
                }
            })
            .collect()
    }

    /// Largest finite |relative error| across bins, skipping empty bins and
    /// bins whose reference power is numerically zero (≤ 10⁻¹⁸ of the peak
    /// bin — e.g. the DC bin of mean-normalized fluctuations, where a
    /// relative error is meaningless).
    pub fn max_relative_error(&self, reference: &PowerSpectrum) -> f64 {
        let peak = reference.power.iter().fold(0.0f64, |a, &b| a.max(b));
        let cutoff = peak * 1e-18;
        self.relative_error(reference)
            .into_iter()
            .zip(&reference.power)
            .filter(|(e, &p)| e.is_finite() && p > cutoff)
            .map(|(e, _)| e.abs())
            .fold(0.0, f64::max)
    }
}

/// Compute the power spectrum of a field with mean-normalized fluctuations.
///
/// If the field mean is (near) zero — e.g. EEG-style signals — the
/// normalization divides by 1 instead of x̄ to avoid blow-up; the spectrum
/// is then of `x - x̄` directly. This matches how practitioners treat
/// zero-mean signals.
pub fn power_spectrum(field: &Field) -> PowerSpectrum {
    let mean = field.mean();
    let denom = if mean.abs() < 1e-30 { 1.0 } else { mean };
    let fluct: Vec<f64> = field
        .data()
        .iter()
        .map(|&v| (v - mean) / denom)
        .collect();
    power_spectrum_of_real(&fluct, field.shape())
}

/// Power spectrum of a real buffer (no normalization), computed from the
/// half spectrum: a Hermitian pair contributes `2·|X_k|²` to its shell
/// (both mates land in the same shell because the radius is even in `k`),
/// so only `rfftn` — half the transform work of [`power_spectrum_of_complex`]
/// — is needed. Shell sums and mode counts are identical to the
/// full-spectrum path up to rounding.
pub fn power_spectrum_of_real(data: &[f64], shape: &[usize]) -> PowerSpectrum {
    let half = rfftn(data, shape);
    bin_radial_half(half.data(), shape)
}

/// Power spectrum of an already-prepared complex buffer (no normalization).
pub fn power_spectrum_of_complex(data: &[Complex], shape: &[usize]) -> PowerSpectrum {
    let spec = fftn(data, shape);
    bin_radial(&spec, shape)
}

/// Radially bin `|X|²` over shells of integer radius in signed-frequency
/// space. The number of bins is `floor(max_radius) + 1` where `max_radius`
/// is the largest representable |k| (the Nyquist corner).
fn bin_radial(spec: &[Complex], shape: &[usize]) -> PowerSpectrum {
    let ndim = shape.len();
    // Max radius: corner of the signed-frequency box.
    let mut max_r2 = 0.0f64;
    for &d in shape {
        let ny = (d / 2) as f64;
        max_r2 += ny * ny;
    }
    // `round` (not `floor`) so the Nyquist-corner mode, whose radius rounds
    // up, still lands in the last bin.
    let nbins = max_r2.sqrt().round() as usize + 1;
    let mut power = vec![0.0; nbins];
    let mut count = vec![0usize; nbins];

    let mut idx = vec![0usize; ndim];
    for &v in spec {
        let mut r2 = 0.0f64;
        for d in 0..ndim {
            let f = signed_freq(idx[d], shape[d]) as f64;
            r2 += f * f;
        }
        let k = r2.sqrt().round() as usize;
        if k < nbins {
            power[k] += v.norm_sqr();
            count[k] += 1;
        }
        for d in (0..ndim).rev() {
            idx[d] += 1;
            if idx[d] < shape[d] {
                break;
            }
            idx[d] = 0;
        }
    }
    PowerSpectrum { power, count }
}

/// Radially bin a half-spectrum buffer (numpy `rfftn` layout). Stored bins
/// whose Hermitian mate lies outside the half layout count with weight 2;
/// the mate has the same shell radius (`signed_freq` is odd under `k → −k`,
/// the radius is even) and the same `|X|²`.
fn bin_radial_half(half: &[Complex], shape: &[usize]) -> PowerSpectrum {
    let ndim = shape.len();
    let last = shape[ndim - 1];
    let h = last / 2 + 1;
    let lead = &shape[..ndim - 1];
    let rows: usize = lead.iter().product();
    let nyq = if last % 2 == 0 { last / 2 } else { usize::MAX };
    let mut max_r2 = 0.0f64;
    for &d in shape {
        let ny = (d / 2) as f64;
        max_r2 += ny * ny;
    }
    let nbins = max_r2.sqrt().round() as usize + 1;
    let mut power = vec![0.0; nbins];
    let mut count = vec![0usize; nbins];

    let mut idx = vec![0usize; lead.len()];
    for r in 0..rows {
        let mut r2_lead = 0.0f64;
        for (d, &n) in lead.iter().enumerate() {
            let f = signed_freq(idx[d], n) as f64;
            r2_lead += f * f;
        }
        for (k, v) in half[r * h..(r + 1) * h].iter().enumerate() {
            // Half-layout bins satisfy k ≤ last/2, so signed_freq(k) = k.
            let f = k as f64;
            let shell = (r2_lead + f * f).sqrt().round() as usize;
            if shell < nbins {
                let w = if k == 0 || k == nyq { 1 } else { 2 };
                power[shell] += w as f64 * v.norm_sqr();
                count[shell] += w;
            }
        }
        for d in (0..lead.len()).rev() {
            idx[d] += 1;
            if idx[d] < lead[d] {
                break;
            }
            idx[d] = 0;
        }
    }
    PowerSpectrum { power, count }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Precision;

    #[test]
    fn pure_tone_power_in_one_bin() {
        // x_n = cos(2π·4n/64) on a DC offset so the mean normalization is
        // well defined; power should concentrate at k = 4.
        let n = 64;
        let data: Vec<f64> = (0..n)
            .map(|i| 10.0 + (2.0 * std::f64::consts::PI * 4.0 * i as f64 / n as f64).cos())
            .collect();
        let f = Field::new(&[n], data, Precision::Double);
        let ps = power_spectrum(&f);
        let total: f64 = ps.power.iter().sum();
        assert!(ps.power[4] / total > 0.999, "P = {:?}", &ps.power[..8]);
    }

    #[test]
    fn white_noise_spectrum_is_flat_ish() {
        use crate::util::XorShift;
        let n = 4096;
        let mut rng = XorShift::new(2);
        let data: Vec<f64> = (0..n).map(|_| 100.0 + rng.normal()).collect();
        let f = Field::new(&[n], data, Precision::Double);
        let ps = power_spectrum(&f);
        // Skip DC; mean power per mode should be roughly constant.
        let per_mode: Vec<f64> = (1..ps.len())
            .filter(|&k| ps.count[k] > 0)
            .map(|k| ps.power[k] / ps.count[k] as f64)
            .collect();
        let mean: f64 = per_mode.iter().sum::<f64>() / per_mode.len() as f64;
        // 1D bins hold a single independent mode (k and N−k are Hermitian
        // twins), so per-bin power is exponentially distributed:
        // P(X < mean/50) ≈ 2%. Check 90% of bins within [mean/50, 50·mean].
        let within = per_mode
            .iter()
            .filter(|&&p| p > mean / 50.0 && p < mean * 50.0)
            .count();
        assert!(
            within as f64 / per_mode.len() as f64 > 0.9,
            "flat fraction {}",
            within as f64 / per_mode.len() as f64
        );
    }

    #[test]
    fn shell_counts_cover_all_modes() {
        let shape = [8usize, 8, 8];
        let f = Field::zeros(&shape, Precision::Single);
        let ps = power_spectrum(&f);
        let covered: usize = ps.count.iter().sum();
        // Every mode whose radius rounds inside the bin range is counted;
        // the 8³ box has corner radius √48 ≈ 6.93 so all 512 modes fit.
        assert_eq!(covered, 512);
    }

    #[test]
    fn half_spectrum_binning_matches_full_path() {
        // The rfft-based spectrum must reproduce the full-complex path to
        // 1e-12 relative (same shells, same counts, same sums up to
        // rounding) — this is the acceptance bar for swapping the engine.
        use crate::util::XorShift;
        for shape in [vec![64usize], vec![45], vec![12, 10], vec![8, 7, 6]] {
            let n: usize = shape.iter().product();
            let mut rng = XorShift::new(77 + n as u64);
            let data: Vec<f64> = (0..n).map(|_| 50.0 + rng.normal()).collect();
            let f = Field::new(&shape, data.clone(), Precision::Double);
            let fast = power_spectrum(&f);
            let mean = f.mean();
            let fluct: Vec<Complex> = data
                .iter()
                .map(|&v| Complex::new((v - mean) / mean, 0.0))
                .collect();
            let slow = power_spectrum_of_complex(&fluct, &shape);
            assert_eq!(fast.count, slow.count, "shape {shape:?}");
            let peak = slow.power.iter().fold(0.0f64, |a, &b| a.max(b));
            for (k, (a, b)) in fast.power.iter().zip(&slow.power).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-12 * peak,
                    "shape {shape:?} bin {k}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn relative_error_identity_is_zero() {
        let n = 32;
        let data: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin() + 5.0).collect();
        let f = Field::new(&[n], data, Precision::Double);
        let ps = power_spectrum(&f);
        let err = ps.max_relative_error(&ps);
        assert_eq!(err, 0.0);
    }
}
