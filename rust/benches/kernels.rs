//! Stage-level microbenchmarks (Table IV / Fig. 9 analogue): FFT sizes,
//! projection kernels, convergence check, edit quantization, and the
//! entropy back end.
//!
//! Custom harness (criterion is unavailable offline):
//! `cargo bench --bench kernels`

use ffcz::correction::QuantizedEdits;
use ffcz::encoding::{huffman_decode, huffman_encode, lossless_compress};
use ffcz::fourier::{fftn_inplace, Complex, Fft, FftDirection};
use ffcz::util::bench::{black_box, Bench};
use ffcz::util::XorShift;

fn main() {
    println!("== kernel benchmarks ==");
    fft_benches();
    projection_benches();
    codec_benches();
}

fn fft_benches() {
    let mut rng = XorShift::new(1);
    for &n in &[4096usize, 65536, 262144] {
        let data: Vec<Complex> = (0..n)
            .map(|_| Complex::new(rng.normal(), rng.normal()))
            .collect();
        let plan = Fft::new(n);
        let mut buf = data.clone();
        let r = Bench::new(format!("fft_1d_{n}"))
            .bytes(n * 16)
            .samples(10)
            .run(|| {
                buf.copy_from_slice(&data);
                plan.process(&mut buf, FftDirection::Forward);
                black_box(buf[0])
            });
        println!("{}", r.report());
    }
    // 3D transform (the experiment workload shape).
    let shape = [64usize, 64, 64];
    let n: usize = shape.iter().product();
    let data: Vec<Complex> = (0..n).map(|_| Complex::new(rng.normal(), 0.0)).collect();
    let mut buf = data.clone();
    let r = Bench::new("fftn_3d_64".to_string())
        .bytes(n * 16)
        .samples(10)
        .run(|| {
            buf.copy_from_slice(&data);
            fftn_inplace(&mut buf, &shape);
            black_box(buf[0])
        });
    println!("{}", r.report());
    // Non-power-of-two (Bluestein) path.
    let n = 100_000;
    let data: Vec<Complex> = (0..n)
        .map(|_| Complex::new(rng.normal(), rng.normal()))
        .collect();
    let plan = Fft::new(n);
    let mut buf = data.clone();
    let r = Bench::new("fft_1d_100000_bluestein".to_string())
        .bytes(n * 16)
        .samples(5)
        .run(|| {
            buf.copy_from_slice(&data);
            plan.process(&mut buf, FftDirection::Forward);
            black_box(buf[0])
        });
    println!("{}", r.report());
}

fn projection_benches() {
    let mut rng = XorShift::new(2);
    let n = 262144;
    let delta: Vec<Complex> = (0..n)
        .map(|_| Complex::new(rng.normal(), rng.normal()))
        .collect();
    let bound = 0.5;

    let mut out = delta.clone();
    let r = Bench::new("project_onto_fcube_256k")
        .bytes(n * 32)
        .elems(n)
        .run(|| {
            for (o, v) in out.iter_mut().zip(&delta) {
                *o = Complex::new(v.re.clamp(-bound, bound), v.im.clamp(-bound, bound));
            }
            black_box(out[0])
        });
    println!("{}", r.report());

    let r = Bench::new("check_convergence_256k")
        .bytes(n * 16)
        .elems(n)
        .run(|| {
            let mut max = 0.0f64;
            for v in &delta {
                max = max.max(v.linf());
            }
            black_box(max)
        });
    println!("{}", r.report());

    let eps: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let mut out_s = eps.clone();
    let r = Bench::new("project_onto_scube_256k")
        .bytes(n * 16)
        .elems(n)
        .run(|| {
            for (o, v) in out_s.iter_mut().zip(&eps) {
                *o = v.clamp(-bound, bound);
            }
            black_box(out_s[0])
        });
    println!("{}", r.report());
}

fn codec_benches() {
    let mut rng = XorShift::new(3);
    let n = 262144;
    // Sparse edit vector (2% density — the realistic regime).
    let edits: Vec<f64> = (0..n)
        .map(|_| {
            if rng.next_f64() < 0.02 {
                rng.normal() * 0.01
            } else {
                0.0
            }
        })
        .collect();
    let r = Bench::new("quantize_edits_256k")
        .bytes(n * 8)
        .elems(n)
        .run(|| black_box(QuantizedEdits::quantize(&edits)));
    println!("{}", r.report());

    let q = QuantizedEdits::quantize(&edits);
    let r = Bench::new("edit_stream_serialize")
        .bytes(n / 8)
        .run(|| black_box(q.to_bytes()));
    println!("{}", r.report());

    // Entropy back end on quantization-code-like data (narrow distribution
    // around the zero code, as real residuals are).
    let syms: Vec<u16> = (0..n)
        .map(|_| {
            let mut s = 32768i32;
            for _ in 0..4 {
                s += (rng.next_u64() % 7) as i32 - 3;
            }
            s as u16
        })
        .collect();
    let r = Bench::new("huffman_encode_256k")
        .bytes(n * 2)
        .run(|| black_box(huffman_encode(&syms)));
    println!("{}", r.report());
    let enc = huffman_encode(&syms);
    let r = Bench::new("huffman_decode_256k")
        .bytes(n * 2)
        .run(|| black_box(huffman_decode(&enc, syms.len()).unwrap()));
    println!("{}", r.report());
    let raw: Vec<u8> = syms.iter().flat_map(|s| s.to_le_bytes()).collect();
    let r = Bench::new("zstd_compress_512KiB")
        .bytes(raw.len())
        .run(|| black_box(lossless_compress(&raw)));
    println!("{}", r.report());
}
