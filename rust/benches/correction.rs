//! End-to-end FFCz correction benchmarks (Table III / Fig. 9 analogue):
//! the pow-2 FFT *kernel* comparison (split-radix-family radix-4 vs the
//! radix-2 oracle baseline, over every last-axis line of 1-D/2-D/3-D pow2
//! volumes), the POCS engine comparison (full-complex reference vs the
//! half-spectrum rfft path, single- and multi-threaded) across
//! 1-D/2-D/3-D pow2 and Bluestein shapes — written to
//! `BENCH_correction.json` so the correction kernel finally has a perf
//! trajectory — plus the full alternating-projection + edit-coding path
//! across Δ regimes and field sizes, native engine vs PJRT artifact when
//! available.
//!
//! `cargo bench --bench correction`            # everything
//! `cargo bench --bench correction -- --quick` # kernel + engine tables,
//!                                             # small shapes (CI smoke)

use ffcz::compressors::{szlike::SzLike, Compressor, ErrorBound};
use ffcz::correction::{
    alternating_projection, alternating_projection_reference, Bounds, PocsParams,
};
use ffcz::data::synth;
use ffcz::fourier::{Complex, Fft, FftDirection};
use ffcz::util::bench::{black_box, Bench};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("FFCZ_BENCH_QUICK").is_ok();
    println!("== correction benchmarks{} ==", if quick { " (quick)" } else { "" });
    let kernel_rows = kernel_comparison(quick);
    pocs_engine_comparison(quick, &kernel_rows);
    if quick {
        return;
    }
    for &scale in &[16usize, 32] {
        bench_scale(scale);
    }
    bench_pjrt();
    bench_predictor_ablation();
}

/// One measured pow-2 kernel configuration.
struct KernelRow {
    name: &'static str,
    shape: Vec<usize>,
    /// "split_radix4" (production radix-4 + radix-2 finish) or "radix2"
    /// (the oracle baseline).
    kernel: &'static str,
    median_s: f64,
    /// Per 1-D line transform (forward + inverse pair counted as two).
    ns_per_transform: f64,
    gbps: f64,
    /// vs the radix-2 baseline on the same shape (1.0 for the baseline).
    speedup_vs_radix2: f64,
}

/// Pow-2 complex-kernel comparison: the production split-radix-family
/// radix-4 kernel vs the radix-2 oracle, measured over every last-axis
/// line of each volume (one forward + inverse sweep per iteration — the
/// line-transform workload the N-D engines are built from). Emits the
/// `kernel_rows` table of `BENCH_correction.json`; the acceptance target
/// is ≥ 1.15× on the 3-D pow-2 shape.
fn kernel_comparison(quick: bool) -> Vec<KernelRow> {
    println!("== pow-2 FFT kernel: split-radix (radix-4) vs radix-2 baseline ==");
    let shapes: Vec<(&'static str, Vec<usize>)> = if quick {
        vec![("1d_pow2", vec![4096]), ("3d_pow2", vec![16, 16, 16])]
    } else {
        vec![
            ("1d_pow2", vec![65536]),
            ("2d_pow2", vec![256, 256]),
            ("3d_pow2", vec![64, 64, 64]),
        ]
    };
    let samples = if quick { 3 } else { 7 };
    let mut rows: Vec<KernelRow> = Vec::new();
    for &(name, ref shape) in &shapes {
        let n: usize = shape.iter().product();
        let len = shape[shape.len() - 1];
        let lines = n / len;
        let plan = Fft::new(len);
        let mut rng = ffcz::util::XorShift::new(9000 + n as u64);
        let mut buf: Vec<Complex> = (0..n)
            .map(|_| Complex::new(rng.normal(), rng.normal()))
            .collect();
        let mut scratch = vec![Complex::ZERO; plan.scratch_len()];
        // One iteration = forward + inverse over every line: 2·lines
        // transforms moving 2·n complex elements (32 B per element pair of
        // passes).
        let transforms = 2 * lines;
        let bytes = 2 * n * 16;

        let mut measure = |kernel: &'static str| {
            let r = Bench::new(format!("fft_{kernel}_{name}"))
                .bytes(bytes)
                .samples(samples)
                .run(|| {
                    for dir in [FftDirection::Forward, FftDirection::Inverse] {
                        for li in 0..lines {
                            let line = &mut buf[li * len..(li + 1) * len];
                            if kernel == "radix2" {
                                plan.process_with_scratch_radix2(line, dir, &mut scratch);
                            } else {
                                plan.process_with_scratch(line, dir, &mut scratch);
                            }
                        }
                    }
                    black_box(buf[0])
                });
            println!("{}", r.report());
            r
        };
        let base = measure("radix2");
        let base_median = base.median.as_secs_f64();
        rows.push(KernelRow {
            name,
            shape: shape.clone(),
            kernel: "radix2",
            median_s: base_median,
            ns_per_transform: base_median / transforms as f64 * 1e9,
            gbps: base.gbps().unwrap_or(0.0),
            speedup_vs_radix2: 1.0,
        });
        let fast = measure("split_radix4");
        let fast_median = fast.median.as_secs_f64();
        let speedup = base_median / fast_median;
        println!("  -> {name} {shape:?}: split-radix {speedup:.2}x vs radix-2");
        rows.push(KernelRow {
            name,
            shape: shape.clone(),
            kernel: "split_radix4",
            median_s: fast_median,
            ns_per_transform: fast_median / transforms as f64 * 1e9,
            gbps: fast.gbps().unwrap_or(0.0),
            speedup_vs_radix2: speedup,
        });
    }
    rows
}

/// One measured configuration of the POCS loop.
struct EngineRow {
    name: &'static str,
    shape: Vec<usize>,
    /// "complex" (reference full-spectrum loop) or "rfft" (half-spectrum).
    path: &'static str,
    threads: usize,
    iterations: usize,
    median_s: f64,
    ns_per_iter: f64,
    /// Effective error-vector traffic: n·8 bytes per iteration.
    gbps: f64,
    /// vs the complex reference on the same shape (1.0 for the reference).
    speedup: f64,
}

/// POCS-loop engine comparison: complex reference vs rfft fast path
/// (threads 1/2/4 on the 3-D shapes), on pow2 and Bluestein shapes across
/// dimensionalities. Emits `BENCH_correction.json` (including the
/// `kernel_rows` table from [`kernel_comparison`]) and prints a one-line
/// summary per shape.
fn pocs_engine_comparison(quick: bool, kernel_rows: &[KernelRow]) {
    println!("== POCS engine: complex reference vs rfft half-spectrum ==");
    // (name, shape, thread counts for the rfft path)
    let shapes: Vec<(&'static str, Vec<usize>, Vec<usize>)> = if quick {
        vec![
            ("1d_pow2", vec![4096], vec![1]),
            ("1d_bluestein", vec![600], vec![1]),
            ("2d_pow2", vec![64, 64], vec![1]),
            ("2d_bluestein", vec![60, 60], vec![1]),
            ("3d_pow2", vec![16, 16, 16], vec![1, 2]),
            ("3d_bluestein", vec![12, 12, 12], vec![1, 2]),
        ]
    } else {
        vec![
            ("1d_pow2", vec![65536], vec![1]),
            ("1d_bluestein", vec![50000], vec![1]),
            ("2d_pow2", vec![256, 256], vec![1, 2, 4]),
            ("2d_bluestein", vec![200, 200], vec![1]),
            ("3d_pow2", vec![64, 64, 64], vec![1, 2, 4]),
            ("3d_bluestein", vec![40, 40, 40], vec![1, 2, 4]),
        ]
    };
    let samples = if quick { 2 } else { 5 };
    let mut rows: Vec<EngineRow> = Vec::new();

    for &(name, ref shape, ref thread_counts) in &shapes {
        let n: usize = shape.iter().product();
        let e = 0.1;
        let mut rng = ffcz::util::XorShift::new(3000 + n as u64);
        let eps0: Vec<f64> = (0..n).map(|_| rng.uniform(-e, e)).collect();
        // Mid regime: tail clipping with a couple of alternations — the
        // shape-independent Δ scaling from the property tests.
        let d = 0.25 * e * (n as f64).sqrt();
        let params = PocsParams {
            spatial: Bounds::Global(e),
            frequency: Bounds::Global(d),
            max_iters: 500,
            threads: 1,
        };

        // Reference full-complex loop.
        let reference = alternating_projection_reference(&eps0, shape, &params);
        let iters = reference.iterations;
        let bytes = n * 8 * iters.max(1);
        let r = Bench::new(format!("pocs_complex_{name}"))
            .bytes(bytes)
            .samples(samples)
            .run(|| black_box(alternating_projection_reference(&eps0, shape, &params)));
        println!("{}   [{} iters]", r.report(), iters);
        let ref_median = r.median.as_secs_f64();
        rows.push(EngineRow {
            name,
            shape: shape.clone(),
            path: "complex",
            threads: 1,
            iterations: iters,
            median_s: ref_median,
            ns_per_iter: ref_median / iters.max(1) as f64 * 1e9,
            gbps: r.gbps().unwrap_or(0.0),
            speedup: 1.0,
        });

        // Half-spectrum fast path at each thread count. Each row is
        // normalized by its *own* iteration count (the engines can differ
        // by one at a rounding-level convergence boundary), and the
        // speedup compares per-iteration times so a convergence-count
        // difference never inflates it.
        let ref_ns_per_iter = ref_median / iters.max(1) as f64 * 1e9;
        for &threads in thread_counts {
            let params_t = PocsParams {
                threads,
                ..params.clone()
            };
            let fast = alternating_projection(&eps0, shape, &params_t);
            let fast_iters = fast.iterations;
            if fast_iters != iters {
                println!(
                    "(note: engines ran {fast_iters} vs {iters} iterations on {name} — \
                     rounding-level convergence-check difference; rows are per-iteration)"
                );
            }
            let r = Bench::new(format!("pocs_rfft_{name}_t{threads}"))
                .bytes(n * 8 * fast_iters.max(1))
                .samples(samples)
                .run(|| black_box(alternating_projection(&eps0, shape, &params_t)));
            let median = r.median.as_secs_f64();
            let ns_per_iter = median / fast_iters.max(1) as f64 * 1e9;
            let speedup = ref_ns_per_iter / ns_per_iter;
            println!(
                "{}   [{} iters, {:.2}x vs complex]",
                r.report(),
                fast_iters,
                speedup
            );
            rows.push(EngineRow {
                name,
                shape: shape.clone(),
                path: "rfft",
                threads,
                iterations: fast_iters,
                median_s: median,
                ns_per_iter,
                gbps: r.gbps().unwrap_or(0.0),
                speedup,
            });
        }
    }

    // One-line summary table.
    println!("-- POCS loop summary (ns/iter) --");
    println!(
        "{:<14} {:>14} {:>14} {:>9} {:>14} {:>9}",
        "shape", "complex", "rfft t1", "speedup", "rfft tmax", "speedup"
    );
    for (name, shape, _) in &shapes {
        let find = |path: &str, max: bool| {
            rows.iter()
                .filter(|r| r.name == *name && r.path == path)
                .max_by_key(|r| if max { r.threads } else { usize::MAX - r.threads })
        };
        let (c, t1, tm) = (
            find("complex", true),
            find("rfft", false),
            find("rfft", true),
        );
        if let (Some(c), Some(t1), Some(tm)) = (c, t1, tm) {
            println!(
                "{:<14} {:>14.0} {:>14.0} {:>8.2}x {:>11.0}/t{} {:>8.2}x",
                format!("{name} {shape:?}"),
                c.ns_per_iter,
                t1.ns_per_iter,
                t1.speedup,
                tm.ns_per_iter,
                tm.threads,
                tm.speedup
            );
        }
    }

    // Hand-rolled JSON (no serde in the offline crate universe).
    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"correction_pocs\",\n");
    json.push_str(&format!("  \"quick\": {quick},\n"));
    json.push_str("  \"kernel_rows\": [\n");
    for (i, k) in kernel_rows.iter().enumerate() {
        let shape: Vec<String> = k.shape.iter().map(|s| s.to_string()).collect();
        json.push_str(&format!(
            "    {{\"name\": \"{}\", \"shape\": [{}], \"kernel\": \"{}\", \
             \"median_s\": {:.6}, \"ns_per_transform\": {:.1}, \"gbps\": {:.4}, \
             \"speedup_vs_radix2\": {:.3}}}{}\n",
            k.name,
            shape.join(", "),
            k.kernel,
            k.median_s,
            k.ns_per_transform,
            k.gbps,
            k.speedup_vs_radix2,
            if i + 1 < kernel_rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n");
    json.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let shape: Vec<String> = r.shape.iter().map(|s| s.to_string()).collect();
        json.push_str(&format!(
            "    {{\"name\": \"{}\", \"shape\": [{}], \"path\": \"{}\", \"threads\": {}, \
             \"iterations\": {}, \"median_s\": {:.6}, \"ns_per_iter\": {:.1}, \
             \"gbps\": {:.4}, \"speedup_vs_complex\": {:.3}}}{}\n",
            r.name,
            shape.join(", "),
            r.path,
            r.threads,
            r.iterations,
            r.median_s,
            r.ns_per_iter,
            r.gbps,
            r.speedup,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    if let Err(e) = std::fs::write("BENCH_correction.json", &json) {
        eprintln!("warning: could not write BENCH_correction.json: {e}");
    } else {
        println!("wrote BENCH_correction.json");
    }
}

fn bench_scale(scale: usize) {
    let field = synth::grf::GrfBuilder::new(&[scale, scale, scale])
        .spectral_index(1.8)
        .lognormal(2.4)
        .seed(101)
        .build();
    let base = SzLike::default();
    let payload = base.compress(&field, ErrorBound::Relative(1e-3)).unwrap();
    let recon = base.decompress(&payload).unwrap();
    let eps0: Vec<f64> = recon
        .data()
        .iter()
        .zip(field.data())
        .map(|(r, x)| r - x)
        .collect();
    let e_abs = ErrorBound::Relative(1e-3).absolute_for(&field);
    let spec_max = {
        let buf: Vec<Complex> = field
            .data()
            .iter()
            .map(|&v| Complex::new(v, 0.0))
            .collect();
        ffcz::fourier::fftn(&buf, field.shape())
            .iter()
            .map(|c| c.abs())
            .fold(0.0f64, f64::max)
    };
    let n = field.len();
    // Δ regimes from Table III: mild tail-clipping to everything-clipped.
    for (regime, frac) in [("mild", 0.3), ("mid", 0.03), ("tiny", 1e-6)] {
        let eps_bench = eps0.clone();
        let (_, rfe) = ffcz::metrics::spectral_metrics(&field, &recon);
        let d_abs = frac * rfe * spec_max;
        let params = PocsParams {
            spatial: Bounds::Global(e_abs),
            frequency: Bounds::Global(d_abs),
            max_iters: 500,
            threads: 1,
        };
        let shape = field.shape().to_vec();
        let r = Bench::new(format!("pocs_{scale}cubed_{regime}"))
            .bytes(n * 8)
            .samples(5)
            .run(|| black_box(alternating_projection(&eps_bench, &shape, &params)));
        let result = alternating_projection(&eps0, &shape, &params);
        println!(
            "{}   [{} iters, {}+{} active edits]",
            r.report(),
            result.iterations,
            result.active_spat,
            result.active_freq
        );
    }
    // Full compress (base + correction + coding) for context.
    let cfg = ffcz::correction::FfczConfig::relative(1e-3, 1e-4);
    let r = Bench::new(format!("full_compress_{scale}cubed"))
        .bytes(field.original_bytes())
        .samples(3)
        .run(|| black_box(ffcz::correction::compress(&field, &base, &cfg).unwrap()));
    println!("{}", r.report());
}

fn bench_pjrt() {
    let dir = std::path::Path::new("artifacts");
    let Ok(mut engine) = ffcz::runtime::PjrtEngine::new(dir) else {
        println!("(artifacts/ not built — PJRT bench skipped)");
        return;
    };
    let shape = [4096usize];
    if !engine.supports_shape(&shape) {
        println!("(no 1d_4096 variant — PJRT bench skipped)");
        return;
    }
    let mut rng = ffcz::util::XorShift::new(5);
    let eps0: Vec<f64> = (0..4096).map(|_| rng.uniform(-0.05, 0.05)).collect();
    // Warm compile outside the timer.
    let _ = engine.correct(&eps0, &shape, 0.05, 1.0).unwrap();
    let r = Bench::new("pjrt_correct_1d_4096")
        .bytes(4096 * 8)
        .samples(10)
        .run(|| black_box(engine.correct(&eps0, &shape, 0.05, 1.0).unwrap()));
    println!("{}", r.report());
    // Native engine on the identical workload.
    let params = PocsParams {
        spatial: Bounds::Global(0.05),
        frequency: Bounds::Global(1.0),
        max_iters: 64,
        threads: 1,
    };
    let r = Bench::new("native_correct_1d_4096")
        .bytes(4096 * 8)
        .samples(10)
        .run(|| black_box(alternating_projection(&eps0, &[4096], &params)));
    println!("{}", r.report());
}

// NOTE: ablation — predictor choice for the sz-like base (DESIGN.md calls
// this out): how does the base predictor affect the downstream FFCz edit
// cost at the same bounds? Run with `cargo bench --bench correction`.
fn bench_predictor_ablation() {
    use ffcz::compressors::szlike::{Predictor, SzLike};
    let field = synth::grf::GrfBuilder::new(&[32, 32, 32])
        .spectral_index(1.8)
        .lognormal(2.4)
        .seed(101)
        .build();
    for (name, pred) in [
        ("lorenzo", Predictor::Lorenzo),
        ("interp", Predictor::Interpolation),
    ] {
        let base = SzLike::with_predictor(pred);
        let payload = base.compress(&field, ErrorBound::Relative(1e-3)).unwrap();
        let recon = base.decompress(&payload).unwrap();
        let cfg = ffcz::correction::FfczConfig::relative(1e-3, 5e-3);
        let archive = ffcz::correction::correct_reconstruction(
            &field,
            &recon,
            base.name(),
            payload.clone(),
            &cfg,
        )
        .unwrap();
        println!(
            "ablation predictor={name}: base {} B, edits {} B, {}+{} active, {} iters",
            payload.len(),
            archive.edit_bytes(),
            archive.stats.active_spat,
            archive.stats.active_freq,
            archive.stats.iterations
        );
    }
}
