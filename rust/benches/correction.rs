//! End-to-end FFCz correction benchmarks (Table III / Fig. 9 analogue):
//! the full alternating-projection + edit-coding path across Δ regimes and
//! field sizes, native engine vs PJRT artifact when available.
//!
//! `cargo bench --bench correction`

use ffcz::compressors::{szlike::SzLike, Compressor, ErrorBound};
use ffcz::correction::{alternating_projection, Bounds, PocsParams};
use ffcz::data::synth;
use ffcz::fourier::Complex;
use ffcz::util::bench::{black_box, Bench};

fn main() {
    println!("== correction benchmarks ==");
    for &scale in &[16usize, 32] {
        bench_scale(scale);
    }
    bench_pjrt();
    bench_predictor_ablation();
}

fn bench_scale(scale: usize) {
    let field = synth::grf::GrfBuilder::new(&[scale, scale, scale])
        .spectral_index(1.8)
        .lognormal(2.4)
        .seed(101)
        .build();
    let base = SzLike::default();
    let payload = base.compress(&field, ErrorBound::Relative(1e-3)).unwrap();
    let recon = base.decompress(&payload).unwrap();
    let eps0: Vec<f64> = recon
        .data()
        .iter()
        .zip(field.data())
        .map(|(r, x)| r - x)
        .collect();
    let e_abs = ErrorBound::Relative(1e-3).absolute_for(&field);
    let spec_max = {
        let buf: Vec<Complex> = field
            .data()
            .iter()
            .map(|&v| Complex::new(v, 0.0))
            .collect();
        ffcz::fourier::fftn(&buf, field.shape())
            .iter()
            .map(|c| c.abs())
            .fold(0.0f64, f64::max)
    };
    let n = field.len();
    // Δ regimes from Table III: mild tail-clipping to everything-clipped.
    for (regime, frac) in [("mild", 0.3), ("mid", 0.03), ("tiny", 1e-6)] {
        let eps_bench = eps0.clone();
        let (_, rfe) = ffcz::metrics::spectral_metrics(&field, &recon);
        let d_abs = frac * rfe * spec_max;
        let params = PocsParams {
            spatial: Bounds::Global(e_abs),
            frequency: Bounds::Global(d_abs),
            max_iters: 500,
        };
        let shape = field.shape().to_vec();
        let r = Bench::new(format!("pocs_{scale}cubed_{regime}"))
            .bytes(n * 8)
            .samples(5)
            .run(|| black_box(alternating_projection(&eps_bench, &shape, &params)));
        let result = alternating_projection(&eps0, &shape, &params);
        println!(
            "{}   [{} iters, {}+{} active edits]",
            r.report(),
            result.iterations,
            result.active_spat,
            result.active_freq
        );
    }
    // Full compress (base + correction + coding) for context.
    let cfg = ffcz::correction::FfczConfig::relative(1e-3, 1e-4);
    let r = Bench::new(format!("full_compress_{scale}cubed"))
        .bytes(field.original_bytes())
        .samples(3)
        .run(|| black_box(ffcz::correction::compress(&field, &base, &cfg).unwrap()));
    println!("{}", r.report());
}

fn bench_pjrt() {
    let dir = std::path::Path::new("artifacts");
    let Ok(mut engine) = ffcz::runtime::PjrtEngine::new(dir) else {
        println!("(artifacts/ not built — PJRT bench skipped)");
        return;
    };
    let shape = [4096usize];
    if !engine.supports_shape(&shape) {
        println!("(no 1d_4096 variant — PJRT bench skipped)");
        return;
    }
    let mut rng = ffcz::util::XorShift::new(5);
    let eps0: Vec<f64> = (0..4096).map(|_| rng.uniform(-0.05, 0.05)).collect();
    // Warm compile outside the timer.
    let _ = engine.correct(&eps0, &shape, 0.05, 1.0).unwrap();
    let r = Bench::new("pjrt_correct_1d_4096")
        .bytes(4096 * 8)
        .samples(10)
        .run(|| black_box(engine.correct(&eps0, &shape, 0.05, 1.0).unwrap()));
    println!("{}", r.report());
    // Native engine on the identical workload.
    let params = PocsParams {
        spatial: Bounds::Global(0.05),
        frequency: Bounds::Global(1.0),
        max_iters: 64,
    };
    let r = Bench::new("native_correct_1d_4096")
        .bytes(4096 * 8)
        .samples(10)
        .run(|| black_box(alternating_projection(&eps0, &[4096], &params)));
    println!("{}", r.report());
}

// NOTE: ablation — predictor choice for the sz-like base (DESIGN.md calls
// this out): how does the base predictor affect the downstream FFCz edit
// cost at the same bounds? Run with `cargo bench --bench correction`.
fn bench_predictor_ablation() {
    use ffcz::compressors::szlike::{Predictor, SzLike};
    let field = synth::grf::GrfBuilder::new(&[32, 32, 32])
        .spectral_index(1.8)
        .lognormal(2.4)
        .seed(101)
        .build();
    for (name, pred) in [
        ("lorenzo", Predictor::Lorenzo),
        ("interp", Predictor::Interpolation),
    ] {
        let base = SzLike::with_predictor(pred);
        let payload = base.compress(&field, ErrorBound::Relative(1e-3)).unwrap();
        let recon = base.decompress(&payload).unwrap();
        let cfg = ffcz::correction::FfczConfig::relative(1e-3, 5e-3);
        let archive = ffcz::correction::correct_reconstruction(
            &field,
            &recon,
            base.name(),
            payload.clone(),
            &cfg,
        )
        .unwrap();
        println!(
            "ablation predictor={name}: base {} B, edits {} B, {}+{} active, {} iters",
            payload.len(),
            archive.edit_bytes(),
            archive.stats.active_spat,
            archive.stats.active_freq,
            archive.stats.iterations
        );
    }
}
