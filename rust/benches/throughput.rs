//! End-to-end throughput benchmarks (Fig. 7 / Table II analogue): base
//! compressor vs FFCz editing per dataset, and the pipelined-vs-sequential
//! makespan comparison.
//!
//! `cargo bench --bench throughput`

use ffcz::compressors::{paper_compressors, ErrorBound};
use ffcz::coordinator::{run_pipeline, ExecMode, PipelineConfig};
use ffcz::correction::{correct_reconstruction, FfczConfig};
use ffcz::data::synth;
use ffcz::codec::CodecChainSpec;
use ffcz::store::{encode_store, write_store, Store, StoreWriteOptions};
use ffcz::util::bench::{black_box, Bench};

fn main() {
    println!("== throughput benchmarks (scale 24) ==");
    per_dataset();
    pipeline_comparison();
    store_comparison();
}

/// Whole-field FFCz compression vs chunked-parallel store encoding at
/// 1/2/4 workers, in-memory vs streamed-to-file. Emits `BENCH_store.json`
/// (median seconds + GB/s + peak payload bytes in flight — the peak-RSS
/// proxy — per configuration) for the perf trajectory.
fn store_comparison() {
    println!("== store benchmarks (32-cubed GRF) ==");
    let field = synth::grf::GrfBuilder::new(&[32, 32, 32])
        .spectral_index(1.8)
        .lognormal(1.2)
        .seed(500)
        .build();
    let bytes = field.original_bytes();
    let spec = CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3));
    // (name, median_s, gbps, peak_payload_bytes)
    let mut rows: Vec<(String, f64, f64, usize)> = Vec::new();

    // Baseline: whole-field compress + correct (single chunk, one worker).
    let whole_opts = StoreWriteOptions::new(&[32, 32, 32]).workers(1);
    let mut peak = 0usize;
    let r = Bench::new("store_whole_field".to_string())
        .bytes(bytes)
        .samples(3)
        .run(|| {
            let (out, _, rep) = encode_store(&field, &spec, &whole_opts).unwrap();
            peak = rep.peak_payload_bytes;
            black_box(out.len())
        });
    println!("{}", r.report());
    rows.push((
        "whole_field".to_string(),
        r.median.as_secs_f64(),
        r.gbps().unwrap_or(0.0),
        peak,
    ));

    // Chunked: 8 chunks of 16³, varying worker count, both write paths.
    let stream_path = std::env::temp_dir().join("ffcz_bench_stream.ffcz");
    for workers in [1usize, 2, 4] {
        let opts = StoreWriteOptions::new(&[16, 16, 16]).workers(workers);

        let mut peak = 0usize;
        let r = Bench::new(format!("store_chunked_16cubed_w{workers}"))
            .bytes(bytes)
            .samples(3)
            .run(|| {
                let (out, _, rep) = encode_store(&field, &spec, &opts).unwrap();
                peak = rep.peak_payload_bytes;
                black_box(out.len())
            });
        println!("{}", r.report());
        rows.push((
            format!("chunked_w{workers}"),
            r.median.as_secs_f64(),
            r.gbps().unwrap_or(0.0),
            peak,
        ));

        // Streaming to a real file: chunk payloads spill as they finish,
        // bounding peak payload memory to the in-flight window.
        let mut peak = 0usize;
        let r = Bench::new(format!("store_streamed_16cubed_w{workers}"))
            .bytes(bytes)
            .samples(3)
            .run(|| {
                let rep = write_store(&field, &spec, &opts, &stream_path).unwrap();
                peak = rep.peak_payload_bytes;
                black_box(rep.total_bytes)
            });
        println!("{}", r.report());
        rows.push((
            format!("streamed_w{workers}"),
            r.median.as_secs_f64(),
            r.gbps().unwrap_or(0.0),
            peak,
        ));
    }
    let _ = std::fs::remove_file(&stream_path);

    // Overlapping read_region windows: decoded-chunk LRU vs cold decode.
    // A sliding 16³ window over the 32³ field re-touches most chunks every
    // step; the byte budget holds the whole decoded field (8 × 16³ chunks).
    {
        let opts = StoreWriteOptions::new(&[16, 16, 16]).workers(2);
        let (store_bytes, _, _) = encode_store(&field, &spec, &opts).unwrap();
        let windows: Vec<[usize; 3]> = (0..=16)
            .step_by(4)
            .map(|o| [o, (o / 2) & !1usize, 0])
            .collect();
        let region = [16usize, 16, 16];
        let read_all = |store: &Store| {
            let mut total = 0usize;
            for w in &windows {
                total += store.read_region(w, &region, 2).unwrap().len();
            }
            total
        };

        let cold = Store::from_bytes(store_bytes.clone()).unwrap();
        let r = Bench::new("read_region_cold".to_string())
            .bytes(windows.len() * region.iter().product::<usize>() * 8)
            .samples(3)
            .run(|| black_box(read_all(&cold)));
        println!("{}   [{} chunk decodes]", r.report(), cold.chunks_decoded());
        rows.push((
            "read_region_cold".to_string(),
            r.median.as_secs_f64(),
            r.gbps().unwrap_or(0.0),
            0,
        ));

        let cached = Store::from_bytes(store_bytes).unwrap();
        cached.set_cache_budget(field.len() * 8);
        read_all(&cached); // warm
        let r = Bench::new("read_region_lru".to_string())
            .bytes(windows.len() * region.iter().product::<usize>() * 8)
            .samples(3)
            .run(|| black_box(read_all(&cached)));
        println!(
            "{}   [{} hits / {} misses, {} decodes total]",
            r.report(),
            cached.cache_hits(),
            cached.cache_misses(),
            cached.chunks_decoded()
        );
        rows.push((
            "read_region_lru".to_string(),
            r.median.as_secs_f64(),
            r.gbps().unwrap_or(0.0),
            0,
        ));
    }

    // Hand-rolled JSON (no serde in the offline crate universe).
    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"store_throughput\",\n");
    json.push_str("  \"field\": [32, 32, 32],\n  \"configs\": [\n");
    for (i, (name, secs, gbps, peak)) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"name\": \"{name}\", \"median_s\": {secs:.6}, \"gbps\": {gbps:.4}, \
             \"peak_payload_bytes\": {peak}}}{}\n",
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    if let Err(e) = std::fs::write("BENCH_store.json", &json) {
        eprintln!("warning: could not write BENCH_store.json: {e}");
    } else {
        println!("wrote BENCH_store.json");
    }
}

fn per_dataset() {
    let suite = synth::benchmark_suite(24);
    for (name, field) in &suite {
        for base in paper_compressors() {
            let payload = base.compress(field, ErrorBound::Relative(1e-3)).unwrap();
            let recon = base.decompress(&payload).unwrap();
            let (_, rfe) = ffcz::metrics::spectral_metrics(field, &recon);
            let cfg = FfczConfig::relative(1e-3, (rfe / 10.0).max(1e-12));

            let r = Bench::new(format!("compress_{}_{}", base.name(), name))
                .bytes(field.original_bytes())
                .samples(3)
                .run(|| black_box(base.compress(field, ErrorBound::Relative(1e-3)).unwrap()));
            println!("{}", r.report());

            let r = Bench::new(format!("edit_{}_{}", base.name(), name))
                .bytes(field.original_bytes())
                .samples(3)
                .run(|| {
                    black_box(
                        correct_reconstruction(
                            field,
                            &recon,
                            base.name(),
                            payload.clone(),
                            &cfg,
                        )
                        .unwrap(),
                    )
                });
            println!("{}", r.report());
        }
    }
}

fn pipeline_comparison() {
    let instances: Vec<_> = (0..4)
        .map(|i| {
            (
                format!("snap{i}"),
                synth::grf::GrfBuilder::new(&[24, 24, 24])
                    .lognormal(2.0)
                    .seed(400 + i as u64)
                    .build(),
            )
        })
        .collect();
    let base = ffcz::compressors::szlike::SzLike::default();
    let bytes: usize = instances.iter().map(|(_, f)| f.original_bytes()).sum();
    for mode in [ExecMode::Pipelined, ExecMode::Sequential] {
        let mut cfg = PipelineConfig::new(FfczConfig::relative(1e-3, 1e-4));
        cfg.mode = mode;
        let insts = instances.clone();
        let r = Bench::new(format!("pipeline_{mode:?}_4x24cubed"))
            .bytes(bytes)
            .samples(3)
            .run(|| black_box(run_pipeline(insts.clone(), &base, &cfg).unwrap()));
        println!("{}", r.report());
    }
}
