//! End-to-end throughput benchmarks (Fig. 7 / Table II analogue): base
//! compressor vs FFCz editing per dataset, the pipelined-vs-sequential
//! makespan comparison, chunked store encoding, and the encode-path
//! scratch-reuse gauge (allocations per steady-state chunk — must be 0).
//!
//! `cargo bench --bench throughput`            # everything
//! `cargo bench --bench throughput -- --quick` # store encode + scratch
//!                                             # gauge only (CI smoke)

use ffcz::compressors::{paper_compressors, ErrorBound};
use ffcz::coordinator::{run_pipeline, ExecMode, PipelineConfig};
use ffcz::correction::{correct_reconstruction, CorrectionScratch, FfczConfig};
use ffcz::data::synth;
use ffcz::codec::{CodecChain, CodecChainSpec};
use ffcz::store::{
    encode_store, write_store, write_store_faulted, FaultPlan, Store, StoreWriteOptions,
};
use ffcz::util::bench::{black_box, Bench};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("FFCZ_BENCH_QUICK").is_ok();
    if quick {
        println!("== throughput benchmarks (quick: store + encode scratch) ==");
        store_comparison(true);
        return;
    }
    println!("== throughput benchmarks (scale 24) ==");
    per_dataset();
    pipeline_comparison();
    store_comparison(false);
}

/// Steady-state encode-path scratch measurement: allocations per chunk
/// after warm-up (the gauge CI asserts is zero) and one-scratch-per-worker
/// reuse vs a fresh scratch per chunk. Returns
/// `(chunk_shape, chunks, allocs_per_chunk, reuse_median_s,
/// fresh_median_s, speedup, total_bytes)`.
fn encode_scratch_gauge(quick: bool) -> (Vec<usize>, usize, f64, f64, f64, f64, usize) {
    let chunk_shape: Vec<usize> = if quick { vec![8, 8, 8] } else { vec![16, 16, 16] };
    let n_chunks = if quick { 4 } else { 8 };
    let chunks: Vec<ffcz::data::Field> = (0..n_chunks)
        .map(|i| {
            synth::grf::GrfBuilder::new(&chunk_shape)
                .spectral_index(1.8)
                .lognormal(1.2)
                .seed(600 + i as u64)
                .build()
        })
        .collect();
    let spec = CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3));
    let chain = CodecChain::from_spec(&spec).unwrap();

    // Gauge: warm on the first chunk, then count scratch allocation events
    // across the rest — steady state must add zero.
    let mut scratch = CorrectionScratch::new();
    chain.encode_chunk_with_scratch(&chunks[0], &mut scratch).unwrap();
    let warm_events = scratch.allocation_events();
    for chunk in &chunks[1..] {
        chain.encode_chunk_with_scratch(chunk, &mut scratch).unwrap();
    }
    let steady_events = scratch.allocation_events() - warm_events;
    let allocs_per_chunk = steady_events as f64 / (n_chunks - 1) as f64;
    println!(
        "encode scratch gauge: {warm_events} warm-up events, {steady_events} steady-state \
         events over {} chunks ({allocs_per_chunk:.3} per chunk)",
        n_chunks - 1
    );

    // Timing: warmed per-worker scratch vs a fresh scratch per chunk.
    let total_bytes: usize = chunks.iter().map(|c| c.original_bytes()).sum();
    let samples = if quick { 3 } else { 5 };
    let r_reuse = Bench::new("encode_scratch_reuse".to_string())
        .bytes(total_bytes)
        .samples(samples)
        .run(|| {
            let mut total = 0usize;
            for chunk in &chunks {
                total += chain
                    .encode_chunk_with_scratch(chunk, &mut scratch)
                    .unwrap()
                    .bytes
                    .len();
            }
            black_box(total)
        });
    println!("{}", r_reuse.report());
    let r_fresh = Bench::new("encode_scratch_fresh".to_string())
        .bytes(total_bytes)
        .samples(samples)
        .run(|| {
            let mut total = 0usize;
            for chunk in &chunks {
                total += chain.encode_chunk(chunk).unwrap().bytes.len();
            }
            black_box(total)
        });
    println!("{}", r_fresh.report());
    let reuse_s = r_reuse.median.as_secs_f64();
    let fresh_s = r_fresh.median.as_secs_f64();
    println!("  -> scratch reuse {:.2}x vs fresh-per-chunk", fresh_s / reuse_s);
    (
        chunk_shape,
        n_chunks,
        allocs_per_chunk,
        reuse_s,
        fresh_s,
        fresh_s / reuse_s,
        total_bytes,
    )
}

/// Whole-field FFCz compression vs chunked-parallel store encoding at
/// 1/2/4 workers, in-memory vs streamed-to-file, plus the encode-path
/// scratch gauge and the archive read server under sustained concurrent
/// load. Emits `BENCH_store.json` (median seconds + GB/s + peak payload
/// bytes in flight — the peak-RSS proxy — per configuration, the
/// `encode_path` object with the allocations-per-chunk gauge, the
/// `remote_read_overhead` object comparing resilient HTTP-range reads
/// against the local file path, and the `server` object with sustained
/// QPS and latency percentiles) for the perf trajectory. Quick mode
/// shrinks the field and skips the LRU sweep.
fn store_comparison(quick: bool) {
    let dim = if quick { 16 } else { 32 };
    let chunk_dim = dim / 2;
    println!("== store benchmarks ({dim}-cubed GRF) ==");
    let field = synth::grf::GrfBuilder::new(&[dim, dim, dim])
        .spectral_index(1.8)
        .lognormal(1.2)
        .seed(500)
        .build();
    let bytes = field.original_bytes();
    let spec = CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3));
    // (name, median_s, gbps, peak_payload_bytes)
    let mut rows: Vec<(String, f64, f64, usize)> = Vec::new();
    let samples = if quick { 2 } else { 3 };

    // Baseline: whole-field compress + correct (single chunk, one worker).
    let whole_opts = StoreWriteOptions::new(&[dim, dim, dim]).workers(1);
    let mut peak = 0usize;
    let r = Bench::new("store_whole_field".to_string())
        .bytes(bytes)
        .samples(samples)
        .run(|| {
            let (out, _, rep) = encode_store(&field, &spec, &whole_opts).unwrap();
            peak = rep.peak_payload_bytes;
            black_box(out.len())
        });
    println!("{}", r.report());
    rows.push((
        "whole_field".to_string(),
        r.median.as_secs_f64(),
        r.gbps().unwrap_or(0.0),
        peak,
    ));

    // Chunked: 8 chunks of (dim/2)³, varying worker count, both write
    // paths.
    let stream_path = std::env::temp_dir().join("ffcz_bench_stream.ffcz");
    let worker_counts: &[usize] = if quick { &[2] } else { &[1, 2, 4] };
    for &workers in worker_counts {
        let opts = StoreWriteOptions::new(&[chunk_dim, chunk_dim, chunk_dim]).workers(workers);

        let mut peak = 0usize;
        let r = Bench::new(format!("store_chunked_{chunk_dim}cubed_w{workers}"))
            .bytes(bytes)
            .samples(samples)
            .run(|| {
                let (out, _, rep) = encode_store(&field, &spec, &opts).unwrap();
                peak = rep.peak_payload_bytes;
                black_box(out.len())
            });
        println!("{}", r.report());
        rows.push((
            format!("chunked_w{workers}"),
            r.median.as_secs_f64(),
            r.gbps().unwrap_or(0.0),
            peak,
        ));

        // Streaming to a real file: chunk payloads spill as they finish,
        // bounding peak payload memory to the in-flight window.
        let mut peak = 0usize;
        let r = Bench::new(format!("store_streamed_{chunk_dim}cubed_w{workers}"))
            .bytes(bytes)
            .samples(samples)
            .run(|| {
                let rep = write_store(&field, &spec, &opts, &stream_path).unwrap();
                peak = rep.peak_payload_bytes;
                black_box(rep.total_bytes)
            });
        println!("{}", r.report());
        rows.push((
            format!("streamed_w{workers}"),
            r.median.as_secs_f64(),
            r.gbps().unwrap_or(0.0),
            peak,
        ));
    }
    let _ = std::fs::remove_file(&stream_path);

    // Overlapping read_region windows: decoded-chunk LRU vs cold decode.
    // A sliding 16³ window over the 32³ field re-touches most chunks every
    // step; the byte budget holds the whole decoded field (8 × 16³ chunks).
    // Skipped in quick mode (the LRU rows are not part of the CI schema
    // floor).
    if !quick {
        let opts = StoreWriteOptions::new(&[16, 16, 16]).workers(2);
        let (store_bytes, _, _) = encode_store(&field, &spec, &opts).unwrap();
        let windows: Vec<[usize; 3]> = (0..=16)
            .step_by(4)
            .map(|o| [o, (o / 2) & !1usize, 0])
            .collect();
        let region = [16usize, 16, 16];
        let read_all = |store: &Store| {
            let mut total = 0usize;
            for w in &windows {
                total += store.read_region(w, &region, 2).unwrap().len();
            }
            total
        };

        let cold = Store::from_bytes(store_bytes.clone()).unwrap();
        let r = Bench::new("read_region_cold".to_string())
            .bytes(windows.len() * region.iter().product::<usize>() * 8)
            .samples(3)
            .run(|| black_box(read_all(&cold)));
        println!("{}   [{} chunk decodes]", r.report(), cold.chunks_decoded());
        rows.push((
            "read_region_cold".to_string(),
            r.median.as_secs_f64(),
            r.gbps().unwrap_or(0.0),
            0,
        ));

        let cached = Store::from_bytes(store_bytes).unwrap();
        cached.set_cache_budget(field.len() * 8);
        read_all(&cached); // warm
        let r = Bench::new("read_region_lru".to_string())
            .bytes(windows.len() * region.iter().product::<usize>() * 8)
            .samples(3)
            .run(|| black_box(read_all(&cached)));
        println!(
            "{}   [{} hits / {} misses, {} decodes total]",
            r.report(),
            cached.cache_hits(),
            cached.cache_misses(),
            cached.chunks_decoded()
        );
        rows.push((
            "read_region_lru".to_string(),
            r.median.as_secs_f64(),
            r.gbps().unwrap_or(0.0),
            0,
        ));
    }

    // Encode-path scratch gauge + reuse timing.
    let (gauge_shape, gauge_chunks, allocs_per_chunk, reuse_s, fresh_s, speedup, _) =
        encode_scratch_gauge(quick);

    // Disabled-mode telemetry cost relative to one chunk encode.
    let encode_chunk_s = reuse_s / gauge_chunks as f64;
    let (telemetry_s, overhead_pct) = telemetry_overhead(encode_chunk_s);

    // Write-path fault-injection plumbing cost: a fault-free injector in
    // the streamed write path vs the plain path.
    let (wf_plain_s, wf_injected_s, wf_overhead_pct) = write_fault_overhead(&field, &spec, quick);

    // Remote read stack cost: resilient HTTP-range reads off a fault-free
    // loopback endpoint vs the same archive from a local file.
    let (rr_local_s, rr_remote_s, rr_overhead_pct) = remote_read_overhead(&field, &spec, quick);

    // Archive read server under sustained concurrent load.
    let (srv_clients, srv_requests, srv_qps, srv_p50_ms, srv_p99_ms) = server_bench(quick);

    // Hand-rolled JSON (no serde in the offline crate universe).
    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"store_throughput\",\n");
    json.push_str(&format!("  \"quick\": {quick},\n"));
    json.push_str(&format!("  \"field\": [{dim}, {dim}, {dim}],\n"));
    let gs: Vec<String> = gauge_shape.iter().map(|s| s.to_string()).collect();
    json.push_str(&format!(
        "  \"encode_path\": {{\"chunk_shape\": [{}], \"chunks\": {gauge_chunks}, \
         \"allocs_per_chunk_steady\": {allocs_per_chunk:.4}, \
         \"reuse_median_s\": {reuse_s:.6}, \"fresh_median_s\": {fresh_s:.6}, \
         \"speedup_vs_fresh\": {speedup:.3}}},\n",
        gs.join(", ")
    ));
    json.push_str(&format!(
        "  \"telemetry_overhead\": {{\"per_chunk_ns\": {:.1}, \
         \"encode_chunk_ms\": {:.4}, \"overhead_pct\": {overhead_pct:.4}}},\n",
        telemetry_s * 1e9,
        encode_chunk_s * 1e3
    ));
    json.push_str(&format!(
        "  \"write_fault_overhead\": {{\"plain_median_s\": {wf_plain_s:.6}, \
         \"injected_median_s\": {wf_injected_s:.6}, \
         \"overhead_pct\": {wf_overhead_pct:.4}}},\n"
    ));
    json.push_str(&format!(
        "  \"remote_read_overhead\": {{\"local_median_s\": {rr_local_s:.6}, \
         \"remote_median_s\": {rr_remote_s:.6}, \
         \"overhead_pct\": {rr_overhead_pct:.4}}},\n"
    ));
    json.push_str(&format!(
        "  \"server\": {{\"clients\": {srv_clients}, \"requests\": {srv_requests}, \
         \"server_qps\": {srv_qps:.1}, \"server_p50_ms\": {srv_p50_ms:.4}, \
         \"server_p99_ms\": {srv_p99_ms:.4}}},\n"
    ));
    json.push_str("  \"configs\": [\n");
    for (i, (name, secs, gbps, peak)) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"name\": \"{name}\", \"median_s\": {secs:.6}, \"gbps\": {gbps:.4}, \
             \"peak_payload_bytes\": {peak}}}{}\n",
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    if let Err(e) = std::fs::write("BENCH_store.json", &json) {
        eprintln!("warning: could not write BENCH_store.json: {e}");
    } else {
        println!("wrote BENCH_store.json");
    }
}

/// Cost of routing the streamed write path through a fault-free
/// `FaultInjector` (the chaos-test configuration) relative to the plain
/// `write_store` path, both streaming the same field to temp files.
/// Returns `(plain_median_s, injected_median_s, overhead_pct)` — the
/// `write_fault_overhead` row of `BENCH_store.json`, whose overhead CI
/// gates at ≤ 2%.
fn write_fault_overhead(
    field: &ffcz::data::Field,
    spec: &CodecChainSpec,
    quick: bool,
) -> (f64, f64, f64) {
    let chunk_dim = field.shape()[0] / 2;
    let opts = StoreWriteOptions::new(&[chunk_dim, chunk_dim, chunk_dim]).workers(2);
    let bytes = field.original_bytes();
    let samples = if quick { 3 } else { 5 };
    let plain_path = std::env::temp_dir().join("ffcz_bench_wf_plain.ffcz");
    let injected_path = std::env::temp_dir().join("ffcz_bench_wf_injected.ffcz");

    let r = Bench::new("store_write_plain".to_string())
        .bytes(bytes)
        .samples(samples)
        .run(|| {
            let rep = write_store(field, spec, &opts, &plain_path).unwrap();
            black_box(rep.total_bytes)
        });
    println!("{}", r.report());
    let plain_s = r.median.as_secs_f64();

    let r = Bench::new("store_write_fault_injected".to_string())
        .bytes(bytes)
        .samples(samples)
        .run(|| {
            let (rep, counts) =
                write_store_faulted(field, spec, &opts, &injected_path, FaultPlan::none())
                    .unwrap();
            assert_eq!(counts.failures, 0, "a fault-free plan injects nothing");
            black_box(rep.total_bytes)
        });
    println!("{}", r.report());
    let injected_s = r.median.as_secs_f64();

    let _ = std::fs::remove_file(&plain_path);
    let _ = std::fs::remove_file(&injected_path);
    let overhead_pct = ((injected_s - plain_s) / plain_s * 100.0).max(0.0);
    (plain_s, injected_s, overhead_pct)
}

/// Cost of the full remote read stack — `ResilientStorage<HttpStorage>`
/// against a fault-free in-process HTTP range endpoint — relative to a
/// plain `FileStorage` open of the same archive, measured over
/// full-field `read_region` calls. The decoded-chunk cache is off by
/// default, so every sample pays the storage path: one range request
/// per chunk payload on pooled keep-alive connections, through the
/// retry/deadline/breaker bookkeeping (decode work is identical on both
/// sides). Returns `(local_median_s, remote_median_s, overhead_pct)` —
/// the `remote_read_overhead` row of `BENCH_store.json`, whose overhead
/// CI gates at ≤ 10%.
fn remote_read_overhead(
    field: &ffcz::data::Field,
    spec: &CodecChainSpec,
    quick: bool,
) -> (f64, f64, f64) {
    use ffcz::store::{HttpRangeServer, HttpStorage, ResilienceOptions, ResilientStorage};
    use std::sync::Arc;

    let chunk_dim = field.shape()[0] / 2;
    let opts = StoreWriteOptions::new(&[chunk_dim, chunk_dim, chunk_dim]).workers(2);
    let path = std::env::temp_dir().join("ffcz_bench_remote.ffcz");
    write_store(field, spec, &opts, &path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    let samples = if quick { 3 } else { 5 };
    let origin = [0usize, 0, 0];
    let region: Vec<usize> = field.shape().to_vec();

    let local = Store::open(&path).unwrap();
    let r = Bench::new("read_region_local_file".to_string())
        .bytes(field.original_bytes())
        .samples(samples)
        .run(|| black_box(local.read_region(&origin, &region, 2).unwrap().len()));
    println!("{}", r.report());
    let local_s = r.median.as_secs_f64();

    let (endpoint, url) = HttpRangeServer::single(bytes).unwrap();
    let http = HttpStorage::open(&url).unwrap();
    let resilient = ResilientStorage::new(Arc::new(http), ResilienceOptions::default());
    let remote = Store::open_storage(Arc::new(resilient)).unwrap();
    let r = Bench::new("read_region_remote_http".to_string())
        .bytes(field.original_bytes())
        .samples(samples)
        .run(|| black_box(remote.read_region(&origin, &region, 2).unwrap().len()));
    println!("{}", r.report());
    let remote_s = r.median.as_secs_f64();
    endpoint.shutdown();
    let _ = std::fs::remove_file(&path);

    let overhead_pct = ((remote_s - local_s) / local_s * 100.0).max(0.0);
    println!("  -> remote read stack overhead {overhead_pct:.2}% over the local file path");
    (local_s, remote_s, overhead_pct)
}

/// Sustained concurrent load on the archive read server: an in-process
/// server over an in-memory archive, hammered by 8 client connections
/// requesting seeded random windows. Reports `(clients, requests, qps,
/// p50_ms, p99_ms)` — the `server` object of `BENCH_store.json`, whose
/// QPS and p99 rows CI schema-checks. The decoded-chunk cache is sized
/// to the field so the numbers measure the request path (framing, region
/// planning, cache hits, response assembly), not cold decode throughput.
fn server_bench(quick: bool) -> (usize, usize, f64, f64, f64) {
    use ffcz::server::{ArchiveServer, Client, ServeOptions};
    use std::sync::Arc;

    let dim = if quick { 16 } else { 24 };
    let field = synth::grf::GrfBuilder::new(&[dim, dim, dim])
        .spectral_index(1.8)
        .lognormal(1.2)
        .seed(700)
        .build();
    let spec = CodecChainSpec::ffcz("sz-like", &FfczConfig::relative(1e-3, 1e-3));
    let opts = StoreWriteOptions::new(&[dim / 2, dim / 2, dim / 2]).workers(2);
    let (bytes, _, _) = encode_store(&field, &spec, &opts).unwrap();
    let store = Store::from_bytes(bytes).unwrap();
    store.set_cache_budget(field.len() * 8);
    let server = ArchiveServer::start(ServeOptions::default()).unwrap();
    server.register("bench", Arc::new(store));
    let addr = server.local_addr().to_string();

    const CLIENTS: usize = 8;
    let per_client = if quick { 50 } else { 200 };
    let window = dim / 2;
    let t0 = std::time::Instant::now();
    let mut latencies: Vec<f64> = Vec::with_capacity(CLIENTS * per_client);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|t| {
                let addr = &addr;
                scope.spawn(move || {
                    let mut client = Client::connect(addr).unwrap();
                    let mut rng = ffcz::util::XorShift::new(0xBE9C + t as u64);
                    let mut lats = Vec::with_capacity(per_client);
                    for _ in 0..per_client {
                        let origin: Vec<usize> = (0..3)
                            .map(|_| rng.below(dim - window + 1))
                            .collect();
                        let shape = [window, window, window];
                        let r0 = std::time::Instant::now();
                        let region = client.read_region("bench", &origin, &shape).unwrap();
                        lats.push(r0.elapsed().as_secs_f64() * 1e3);
                        black_box(region.len());
                    }
                    lats
                })
            })
            .collect();
        for handle in handles {
            latencies.extend(handle.join().unwrap());
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    server.shutdown();

    let requests = CLIENTS * per_client;
    let qps = requests as f64 / wall.max(1e-9);
    latencies.sort_by(|a, b| a.total_cmp(b));
    let pct = |p: f64| latencies[((latencies.len() - 1) as f64 * p) as usize];
    let (p50, p99) = (pct(0.50), pct(0.99));
    println!(
        "server bench: {CLIENTS} clients x {per_client} requests of {window}^3 windows: \
         {qps:.0} req/s sustained, p50 {p50:.3} ms, p99 {p99:.3} ms"
    );
    (CLIENTS, requests, qps, p50, p99)
}

/// Disabled-mode telemetry cost per chunk: time a loop of the telemetry
/// operations one chunk encode performs (stage span guards + counter /
/// gauge / histogram bumps) with tracing off, and express it as a
/// percentage of the measured per-chunk encode wall time. Recording is
/// off by default, so this is the price every un-traced run pays — the
/// quick bench emits it as the `telemetry_overhead` row of
/// `BENCH_store.json` and CI gates it at ≤ 2%.
fn telemetry_overhead(encode_chunk_s: f64) -> (f64, f64) {
    ffcz::telemetry::trace::disable();
    let counter = ffcz::telemetry::counter("bench.telemetry.overhead_probe");
    let gauge = ffcz::telemetry::gauge("bench.telemetry.overhead_gauge");
    let hist = ffcz::telemetry::histogram("bench.telemetry.overhead_hist");
    let iters = 200_000u64;
    let t0 = std::time::Instant::now();
    for i in 0..iters {
        // One chunk's worth of telemetry traffic on the store encode
        // path: six span guards (inert while tracing is off), the encode
        // counters, the peak gauge, and the chunk-time histogram.
        let _s1 = ffcz::telemetry::span("bench.overhead.encode");
        let _s2 = ffcz::telemetry::span("bench.overhead.base");
        let _s3 = ffcz::telemetry::span("bench.overhead.correct");
        let _s4 = ffcz::telemetry::span("bench.overhead.verify");
        let _s5 = ffcz::telemetry::span("bench.overhead.lossless");
        let _s6 = ffcz::telemetry::span("bench.overhead.sink");
        counter.incr();
        counter.add(black_box(i) & 0xF);
        counter.incr();
        counter.add(3);
        counter.incr();
        counter.incr();
        counter.incr();
        counter.incr();
        gauge.max(black_box(i));
        hist.record(black_box(i));
    }
    let per_op_s = t0.elapsed().as_secs_f64() / iters as f64;
    let overhead_pct = 100.0 * per_op_s / encode_chunk_s.max(1e-12);
    println!(
        "telemetry overhead (disabled): {:.1} ns per chunk = {overhead_pct:.4}% of the \
         {:.3} ms per-chunk encode",
        per_op_s * 1e9,
        encode_chunk_s * 1e3
    );
    (per_op_s, overhead_pct)
}

fn per_dataset() {
    let suite = synth::benchmark_suite(24);
    for (name, field) in &suite {
        for base in paper_compressors() {
            let payload = base.compress(field, ErrorBound::Relative(1e-3)).unwrap();
            let recon = base.decompress(&payload).unwrap();
            let (_, rfe) = ffcz::metrics::spectral_metrics(field, &recon);
            let cfg = FfczConfig::relative(1e-3, (rfe / 10.0).max(1e-12));

            let r = Bench::new(format!("compress_{}_{}", base.name(), name))
                .bytes(field.original_bytes())
                .samples(3)
                .run(|| black_box(base.compress(field, ErrorBound::Relative(1e-3)).unwrap()));
            println!("{}", r.report());

            let r = Bench::new(format!("edit_{}_{}", base.name(), name))
                .bytes(field.original_bytes())
                .samples(3)
                .run(|| {
                    black_box(
                        correct_reconstruction(
                            field,
                            &recon,
                            base.name(),
                            payload.clone(),
                            &cfg,
                        )
                        .unwrap(),
                    )
                });
            println!("{}", r.report());
        }
    }
}

fn pipeline_comparison() {
    let instances: Vec<_> = (0..4)
        .map(|i| {
            (
                format!("snap{i}"),
                synth::grf::GrfBuilder::new(&[24, 24, 24])
                    .lognormal(2.0)
                    .seed(400 + i as u64)
                    .build(),
            )
        })
        .collect();
    let base = ffcz::compressors::szlike::SzLike::default();
    let bytes: usize = instances.iter().map(|(_, f)| f.original_bytes()).sum();
    for mode in [ExecMode::Pipelined, ExecMode::Sequential] {
        let mut cfg = PipelineConfig::new(FfczConfig::relative(1e-3, 1e-4));
        cfg.mode = mode;
        let insts = instances.clone();
        let r = Bench::new(format!("pipeline_{mode:?}_4x24cubed"))
            .bytes(bytes)
            .samples(3)
            .run(|| black_box(run_pipeline(insts.clone(), &base, &cfg).unwrap()));
        println!("{}", r.report());
    }
}
