//! End-to-end throughput benchmarks (Fig. 7 / Table II analogue): base
//! compressor vs FFCz editing per dataset, and the pipelined-vs-sequential
//! makespan comparison.
//!
//! `cargo bench --bench throughput`

use ffcz::compressors::{paper_compressors, ErrorBound};
use ffcz::coordinator::{run_pipeline, ExecMode, PipelineConfig};
use ffcz::correction::{correct_reconstruction, FfczConfig};
use ffcz::data::synth;
use ffcz::util::bench::{black_box, Bench};

fn main() {
    println!("== throughput benchmarks (scale 24) ==");
    per_dataset();
    pipeline_comparison();
}

fn per_dataset() {
    let suite = synth::benchmark_suite(24);
    for (name, field) in &suite {
        for base in paper_compressors() {
            let payload = base.compress(field, ErrorBound::Relative(1e-3)).unwrap();
            let recon = base.decompress(&payload).unwrap();
            let (_, rfe) = ffcz::metrics::spectral_metrics(field, &recon);
            let cfg = FfczConfig::relative(1e-3, (rfe / 10.0).max(1e-12));

            let r = Bench::new(format!("compress_{}_{}", base.name(), name))
                .bytes(field.original_bytes())
                .samples(3)
                .run(|| black_box(base.compress(field, ErrorBound::Relative(1e-3)).unwrap()));
            println!("{}", r.report());

            let r = Bench::new(format!("edit_{}_{}", base.name(), name))
                .bytes(field.original_bytes())
                .samples(3)
                .run(|| {
                    black_box(
                        correct_reconstruction(
                            field,
                            &recon,
                            base.name(),
                            payload.clone(),
                            &cfg,
                        )
                        .unwrap(),
                    )
                });
            println!("{}", r.report());
        }
    }
}

fn pipeline_comparison() {
    let instances: Vec<_> = (0..4)
        .map(|i| {
            (
                format!("snap{i}"),
                synth::grf::GrfBuilder::new(&[24, 24, 24])
                    .lognormal(2.0)
                    .seed(400 + i as u64)
                    .build(),
            )
        })
        .collect();
    let base = ffcz::compressors::szlike::SzLike::default();
    let bytes: usize = instances.iter().map(|(_, f)| f.original_bytes()).sum();
    for mode in [ExecMode::Pipelined, ExecMode::Sequential] {
        let mut cfg = PipelineConfig::new(FfczConfig::relative(1e-3, 1e-4));
        cfg.mode = mode;
        let insts = instances.clone();
        let r = Bench::new(format!("pipeline_{mode:?}_4x24cubed"))
            .bytes(bytes)
            .samples(3)
            .run(|| black_box(run_pipeline(insts.clone(), &base, &cfg).unwrap()));
        println!("{}", r.report());
    }
}
