//! `cargo run -p xtask -- lint [--json] [--root DIR]`
//!
//! Runs the ffcz-lint rules (see `docs/ANALYSIS.md`) over the repo and
//! exits nonzero on any finding — findings are always errors, there is
//! no warning mode. `--json` prints the stable machine-readable report
//! instead of the human rendering.

use std::path::PathBuf;
use std::process::ExitCode;

fn usage() -> ExitCode {
    eprintln!("usage: cargo run -p xtask -- lint [--json] [--root DIR]");
    ExitCode::from(2)
}

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    let Some(command) = args.next() else {
        return usage();
    };
    if command != "lint" {
        eprintln!("unknown command `{command}`");
        return usage();
    }
    let mut json = false;
    // The xtask manifest lives at <repo>/rust/xtask, so the repo root
    // is two levels up; `--root` overrides for out-of-tree checkouts.
    let mut root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    root.pop();
    root.pop();
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--json" => json = true,
            "--root" => match args.next() {
                Some(dir) => root = PathBuf::from(dir),
                None => return usage(),
            },
            other => {
                eprintln!("unknown flag `{other}`");
                return usage();
            }
        }
    }

    let report = match xtask::run_lint(&root) {
        Ok(report) => report,
        Err(e) => {
            eprintln!("ffcz-lint: {e}");
            return ExitCode::from(2);
        }
    };

    if json {
        println!("{}", report.to_json());
    } else {
        for f in &report.findings {
            if f.line > 0 {
                println!("{}:{}: [{}] {}", f.path, f.line, f.rule, f.message);
            } else {
                println!("{}: [{}] {}", f.path, f.rule, f.message);
            }
        }
        let audited = report.unsafe_sites.len();
        let commented = report.unsafe_sites.iter().filter(|s| s.has_safety).count();
        println!(
            "ffcz-lint: {} file(s), {} finding(s), {} suppressed, {}/{} unsafe site(s) documented",
            report.files_scanned,
            report.findings.len(),
            report.suppressed,
            commented,
            audited
        );
    }
    if report.findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
