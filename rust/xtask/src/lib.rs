//! `ffcz-lint`: repo-invariant static analysis for the ffcz crate.
//!
//! A dependency-free line/token scanner (no `syn`) over `rust/src/`
//! enforcing the repo-specific rules described in `docs/ANALYSIS.md`:
//!
//! * `telemetry-drift` (L1) — telemetry names in code ↔ the
//!   `docs/TELEMETRY.md` glossaries, bidirectionally;
//! * `format-constants` (L2) — `const` values ↔ the `docs/FORMAT.md`
//!   § 1.2 normative table;
//! * `unsafe-audit` (L3) — every `unsafe` site carries `// SAFETY:`,
//!   plus a machine-readable inventory of all sites;
//! * `diag-hygiene` (L4) — `println!`/`eprintln!` only in
//!   `telemetry/diag.rs` and the checked-in allowlist;
//! * `panic-policy` (L5) — `.unwrap()`/`.expect(` in decode/read paths
//!   ratcheted against `rust/lint/panic_allow.txt`.
//!
//! Findings are always errors (`cargo run -p xtask -- lint` exits
//! nonzero on any); suppress a single line with
//! `// ffcz-lint: allow(<rule>)`.

use std::fmt::Write as _;
use std::fs;
use std::path::{Path, PathBuf};

pub mod docparse;
pub mod rules;
pub mod scan;

use scan::SourceFile;

/// One lint finding.
#[derive(Debug)]
pub struct Finding {
    pub rule: &'static str,
    /// Repo-root-relative path the finding anchors to (a source file,
    /// a doc, or an allowlist).
    pub path: String,
    /// 1-based line, 0 when the finding has no line anchor.
    pub line: usize,
    pub message: String,
}

/// One `unsafe` site from the L3 inventory.
#[derive(Debug)]
pub struct UnsafeSite {
    pub path: String,
    pub line: usize,
    /// `"block"`, `"fn"`, or `"impl"`.
    pub kind: String,
    pub has_safety: bool,
}

/// Routes rule output and applies per-line suppressions.
#[derive(Default)]
pub struct Collector {
    pub findings: Vec<Finding>,
    pub suppressed: usize,
}

impl Collector {
    pub fn new() -> Self {
        Collector::default()
    }

    /// Emit a finding anchored in a scanned source file, honoring its
    /// `// ffcz-lint: allow(…)` suppressions.
    pub fn emit(&mut self, file: &SourceFile, rule: &'static str, line: usize, message: String) {
        if file.is_suppressed(rule, line) {
            self.suppressed += 1;
        } else {
            self.findings.push(Finding {
                rule,
                path: file.path.clone(),
                line,
                message,
            });
        }
    }

    /// Emit a finding anchored somewhere suppressions cannot reach (a
    /// doc table row, an allowlist row, a whole file).
    pub fn emit_at(&mut self, rule: &'static str, path: &str, line: usize, message: String) {
        self.findings.push(Finding {
            rule,
            path: path.to_string(),
            line,
            message,
        });
    }
}

/// The full lint result.
pub struct Report {
    pub findings: Vec<Finding>,
    pub suppressed: usize,
    pub unsafe_sites: Vec<UnsafeSite>,
    pub files_scanned: usize,
}

impl Report {
    /// Stable JSON for CI: findings sorted by (path, line, rule), the
    /// unsafe inventory by (path, line), all strings escaped.
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n  \"findings\": [");
        for (i, f) in self.findings.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(
                s,
                "\n    {{\"rule\": \"{}\", \"path\": \"{}\", \"line\": {}, \"message\": \"{}\"}}",
                json_escape(f.rule),
                json_escape(&f.path),
                f.line,
                json_escape(&f.message)
            );
        }
        s.push_str(if self.findings.is_empty() { "],\n" } else { "\n  ],\n" });
        s.push_str("  \"unsafe_inventory\": [");
        for (i, u) in self.unsafe_sites.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(
                s,
                "\n    {{\"path\": \"{}\", \"line\": {}, \"kind\": \"{}\", \"has_safety\": {}}}",
                json_escape(&u.path),
                u.line,
                json_escape(&u.kind),
                u.has_safety
            );
        }
        s.push_str(if self.unsafe_sites.is_empty() { "],\n" } else { "\n  ],\n" });
        let _ = write!(
            s,
            "  \"summary\": {{\"files_scanned\": {}, \"findings\": {}, \"suppressed\": {}, \"unsafe_sites\": {}}}\n}}",
            self.files_scanned,
            self.findings.len(),
            self.suppressed,
            self.unsafe_sites.len()
        );
        s
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Every `.rs` file under `rust/src/`, sorted for determinism.
fn rust_sources(root: &Path) -> Result<Vec<PathBuf>, String> {
    let src = root.join("rust").join("src");
    let mut out = Vec::new();
    let mut stack = vec![src.clone()];
    while let Some(dir) = stack.pop() {
        let entries =
            fs::read_dir(&dir).map_err(|e| format!("cannot read {}: {e}", dir.display()))?;
        for entry in entries {
            let path = entry.map_err(|e| format!("readdir {}: {e}", dir.display()))?.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|x| x == "rs") {
                out.push(path);
            }
        }
    }
    if out.is_empty() {
        return Err(format!("no Rust sources under {}", src.display()));
    }
    out.sort();
    Ok(out)
}

fn rel_path(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    rel.to_string_lossy().replace('\\', "/")
}

/// Run every rule against the repo at `root` (the directory holding
/// `rust/` and `docs/`).
pub fn run_lint(root: &Path) -> Result<Report, String> {
    let mut col = Collector::new();
    let mut files = Vec::new();
    for path in rust_sources(root)? {
        let text =
            fs::read_to_string(&path).map_err(|e| format!("read {}: {e}", path.display()))?;
        files.push(scan::scan_str(&rel_path(root, &path), &text));
    }

    match fs::read_to_string(root.join("docs/TELEMETRY.md")) {
        Ok(doc) => {
            let glossary = docparse::telemetry_glossary(&doc);
            if glossary.spans.is_empty() || glossary.metrics.is_empty() {
                col.emit_at(
                    rules::LINT_CONFIG,
                    "docs/TELEMETRY.md",
                    0,
                    "span/metric glossary tables not found (did a heading change?)".to_string(),
                );
            } else {
                rules::telemetry_drift(&files, &glossary, "docs/TELEMETRY.md", &mut col);
            }
        }
        Err(e) => col.emit_at(
            rules::LINT_CONFIG,
            "docs/TELEMETRY.md",
            0,
            format!("cannot read the telemetry glossary: {e}"),
        ),
    }

    match fs::read_to_string(root.join("docs/FORMAT.md")) {
        Ok(doc) => {
            let rows = docparse::format_constants(&doc);
            if rows.is_empty() {
                col.emit_at(
                    rules::LINT_CONFIG,
                    "docs/FORMAT.md",
                    0,
                    "§ 1.2 constants table not found".to_string(),
                );
            } else {
                rules::format_constants_rule(&files, &rows, "docs/FORMAT.md", &mut col);
            }
        }
        Err(e) => col.emit_at(
            rules::LINT_CONFIG,
            "docs/FORMAT.md",
            0,
            format!("cannot read the format spec: {e}"),
        ),
    }

    let mut unsafe_sites = Vec::new();
    rules::unsafe_audit(&files, &mut col, &mut unsafe_sites);

    match fs::read_to_string(root.join("rust/lint/print_allow.txt")) {
        Ok(text) => {
            let allow = rules::PathAllowlist::parse(&text);
            rules::diag_hygiene(&files, &allow, &mut col);
        }
        Err(e) => col.emit_at(
            rules::LINT_CONFIG,
            "rust/lint/print_allow.txt",
            0,
            format!("cannot read the print allowlist: {e}"),
        ),
    }

    let panic_allow_path = "rust/lint/panic_allow.txt";
    let panic_allow = match fs::read_to_string(root.join(panic_allow_path)) {
        Ok(text) => rules::parse_panic_allowlist(&text, panic_allow_path, &mut col),
        Err(e) => {
            col.emit_at(
                rules::LINT_CONFIG,
                panic_allow_path,
                0,
                format!("cannot read the panic allowlist: {e}"),
            );
            Vec::new()
        }
    };
    rules::panic_policy(&files, &panic_allow, panic_allow_path, &mut col);

    col.findings
        .sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    unsafe_sites.sort_by(|a, b| (&a.path, a.line).cmp(&(&b.path, b.line)));
    Ok(Report {
        findings: col.findings,
        suppressed: col.suppressed,
        unsafe_sites,
        files_scanned: files.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_output_is_escaped_and_shaped() {
        let report = Report {
            findings: vec![Finding {
                rule: "panic-policy",
                path: "a\"b.rs".to_string(),
                line: 3,
                message: "uses \\ and \"quotes\"".to_string(),
            }],
            suppressed: 1,
            unsafe_sites: vec![UnsafeSite {
                path: "u.rs".to_string(),
                line: 9,
                kind: "block".to_string(),
                has_safety: true,
            }],
            files_scanned: 2,
        };
        let json = report.to_json();
        assert!(json.contains("\"a\\\"b.rs\""), "{json}");
        assert!(json.contains("uses \\\\ and \\\"quotes\\\""), "{json}");
        assert!(json.contains("\"has_safety\": true"), "{json}");
        assert!(json.contains("\"files_scanned\": 2"), "{json}");
        // Shape check with the crate's own hand-rolled consumer style:
        // balanced braces/brackets, no raw control characters.
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }

    #[test]
    fn empty_report_is_valid_json_too() {
        let report = Report {
            findings: Vec::new(),
            suppressed: 0,
            unsafe_sites: Vec::new(),
            files_scanned: 0,
        };
        let json = report.to_json();
        assert!(json.contains("\"findings\": []"), "{json}");
        assert!(json.contains("\"unsafe_inventory\": []"), "{json}");
    }
}
