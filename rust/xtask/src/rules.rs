//! The ffcz-lint rules. Every rule consumes the [`SourceFile`] line
//! model plus whatever normative input it checks against (a doc
//! glossary, a constants table, a checked-in allowlist) and reports
//! through the [`Collector`], which routes per-line suppressions.

use crate::docparse::{self, DocConstant, TelemetryGlossary};
use crate::scan::{find_token, has_token, SourceFile};
use crate::{Collector, UnsafeSite};

/// L1 — metric/span names in code ↔ `docs/TELEMETRY.md` glossaries.
pub const TELEMETRY_DRIFT: &str = "telemetry-drift";
/// L2 — format constants ↔ `docs/FORMAT.md` § 1.2 table.
pub const FORMAT_CONSTANTS: &str = "format-constants";
/// L3 — every `unsafe` site carries an adjacent `// SAFETY:` comment.
pub const UNSAFE_AUDIT: &str = "unsafe-audit";
/// L4 — no `println!`/`eprintln!` outside `telemetry/diag.rs`.
pub const DIAG_HYGIENE: &str = "diag-hygiene";
/// L5 — no `unwrap()`/`expect()` in library decode/read paths.
pub const PANIC_POLICY: &str = "panic-policy";
/// Broken lint inputs (missing docs, malformed allowlists).
pub const LINT_CONFIG: &str = "lint-config";

// ---------------------------------------------------------------- L1 --

const TELEMETRY_CALLS: [&str; 5] = [
    "counter(",
    "gauge(",
    "histogram(",
    "span(",
    "span_with_parent(",
];

/// L1: every telemetry name constructed in code must appear in the
/// glossaries, and every documented name must be constructed somewhere.
/// Names built with `format!` become segment patterns whose `{…}`
/// segments match any glossary segment.
pub fn telemetry_drift(
    files: &[SourceFile],
    glossary: &TelemetryGlossary,
    doc_path: &str,
    out: &mut Collector,
) {
    // (name or pattern, file index, line)
    let mut literals: Vec<(String, usize, usize)> = Vec::new();
    let mut patterns: Vec<(String, usize, usize)> = Vec::new();
    for (fi, file) in files.iter().enumerate() {
        for line in &file.lines {
            if line.in_test || line.strings.is_empty() {
                continue;
            }
            let call = TELEMETRY_CALLS.iter().any(|t| has_token(&line.code, t));
            let fmt = has_token(&line.code, "format!(");
            if !call && !fmt {
                continue;
            }
            for s in &line.strings {
                if call && docparse::is_metric_shaped(s) {
                    literals.push((s.clone(), fi, line.number));
                } else if fmt && s.contains('{') {
                    if is_pattern_shaped(s) {
                        patterns.push((s.clone(), fi, line.number));
                    }
                } else if fmt && docparse::is_metric_shaped(s) {
                    literals.push((s.clone(), fi, line.number));
                }
            }
        }
    }
    let documented: Vec<&str> = glossary.all().map(|d| d.name.as_str()).collect();
    for (name, fi, line) in &literals {
        if !documented.iter().any(|d| d == name) {
            out.emit(
                &files[*fi],
                TELEMETRY_DRIFT,
                *line,
                format!("telemetry name `{name}` is not in the {doc_path} glossary"),
            );
        }
    }
    for (pat, fi, line) in &patterns {
        if !documented.iter().any(|d| pattern_matches(pat, d)) {
            out.emit(
                &files[*fi],
                TELEMETRY_DRIFT,
                *line,
                format!("telemetry name pattern `{pat}` matches nothing in the {doc_path} glossary"),
            );
        }
    }
    for doc in glossary.all() {
        let covered = literals.iter().any(|(n, ..)| n == &doc.name)
            || patterns.iter().any(|(p, ..)| pattern_matches(p, &doc.name));
        if !covered {
            out.emit_at(
                TELEMETRY_DRIFT,
                doc_path,
                doc.line,
                format!(
                    "documented telemetry name `{}` is never constructed by the code",
                    doc.name
                ),
            );
        }
    }
}

/// A `format!` literal that plausibly builds a telemetry name: dotted
/// lowercase segments where `{…}` placeholders are whole segments, at
/// least three segments, at least two of them literal words. Filters
/// out ordinary interpolations like `"{}.ffcz"`.
fn is_pattern_shaped(s: &str) -> bool {
    let mut literal_words = 0;
    let mut segments = 0;
    for seg in s.split('.') {
        if seg.is_empty() {
            return false;
        }
        segments += 1;
        let placeholder = seg.starts_with('{') && seg.ends_with('}') && seg.len() >= 2;
        let body = if placeholder { &seg[1..seg.len() - 1] } else { seg };
        if !body
            .chars()
            .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
        {
            return false;
        }
        if !placeholder && body.chars().any(|c| c.is_ascii_lowercase()) {
            literal_words += 1;
        }
    }
    segments >= 3 && literal_words >= 2
}

/// Segment-wise match of a `format!` pattern against a concrete name:
/// `{…}` segments are wildcards, everything else is literal.
fn pattern_matches(pat: &str, name: &str) -> bool {
    let ps: Vec<&str> = pat.split('.').collect();
    let ns: Vec<&str> = name.split('.').collect();
    ps.len() == ns.len()
        && ps
            .iter()
            .zip(&ns)
            .all(|(p, n)| (p.starts_with('{') && p.ends_with('}')) || p == n)
}

// ---------------------------------------------------------------- L2 --

/// L2: every row of the FORMAT.md § 1.2 constants table must have a
/// same-named `const` in the code with an equal value (numeric values
/// compared after radix normalization, magics as byte strings).
pub fn format_constants_rule(
    files: &[SourceFile],
    rows: &[DocConstant],
    doc_path: &str,
    out: &mut Collector,
) {
    // (name, code value text, string value if the literal was a string,
    //  file index, line)
    let mut consts: Vec<(String, String, Option<String>, usize, usize)> = Vec::new();
    for (fi, file) in files.iter().enumerate() {
        for line in &file.lines {
            if line.in_test {
                continue;
            }
            for at in find_token(&line.code, "const ") {
                if let Some((name, value)) = parse_const(&line.code[at..]) {
                    let string = if value == "b\"\"" || value == "\"\"" {
                        line.strings.first().cloned()
                    } else {
                        None
                    };
                    consts.push((name, value, string, fi, line.number));
                }
            }
        }
    }
    for row in rows {
        let hits: Vec<_> = consts.iter().filter(|(n, ..)| n == &row.name).collect();
        if hits.is_empty() {
            out.emit_at(
                FORMAT_CONSTANTS,
                doc_path,
                row.line,
                format!(
                    "documented constant `{}` has no `const {}` definition in the code",
                    row.name, row.name
                ),
            );
            continue;
        }
        for (name, value, string, fi, line) in hits {
            if !values_equal(&row.value, value, string.as_deref()) {
                out.emit(
                    &files[*fi],
                    FORMAT_CONSTANTS,
                    *line,
                    format!(
                        "`const {name}` is `{value}` but {doc_path} documents `{}`",
                        row.value
                    ),
                );
            }
        }
    }
}

/// Parse `const NAME: TYPE = VALUE;` from code starting at `const `.
/// Only SCREAMING_CASE names count (skips `const fn` and const
/// generics, which have no `= …;` of their own).
fn parse_const(code: &str) -> Option<(String, String)> {
    let rest = code.strip_prefix("const ")?;
    let name: String = rest
        .trim_start()
        .chars()
        .take_while(|&c| crate::scan::is_word(c))
        .collect();
    if name.is_empty() || !name.chars().all(|c| c.is_ascii_uppercase() || c.is_ascii_digit() || c == '_') {
        return None;
    }
    let after_colon = rest.find(':')?;
    let eq = rest[after_colon..].find('=')? + after_colon;
    let semi = rest[eq..].find(';')? + eq;
    Some((name, rest[eq + 1..semi].trim().to_string()))
}

fn values_equal(doc: &str, code_value: &str, code_string: Option<&str>) -> bool {
    if let Some(s) = code_string {
        return s == doc;
    }
    match (parse_int(doc), parse_int(code_value)) {
        (Some(a), Some(b)) => a == b,
        _ => doc == code_value,
    }
}

/// Radix-normalizing integer parse: `0x01` == `0b0000_0001` == `1`,
/// underscores and type suffixes stripped.
fn parse_int(s: &str) -> Option<u128> {
    let mut t: String = s.trim().chars().filter(|&c| c != '_').collect();
    for suffix in [
        "usize", "u128", "u64", "u32", "u16", "u8", "isize", "i128", "i64", "i32", "i16", "i8",
    ] {
        if let Some(head) = t.strip_suffix(suffix) {
            if head.chars().next_back().is_some_and(|c| c.is_ascii_hexdigit()) {
                t = head.to_string();
            }
            break;
        }
    }
    let (digits, radix) = if let Some(h) = t.strip_prefix("0x") {
        (h.to_string(), 16)
    } else if let Some(b) = t.strip_prefix("0b") {
        (b.to_string(), 2)
    } else if let Some(o) = t.strip_prefix("0o") {
        (o.to_string(), 8)
    } else {
        (t, 10)
    };
    if digits.is_empty() {
        return None;
    }
    u128::from_str_radix(&digits, radix).ok()
}

// ---------------------------------------------------------------- L3 --

/// L3: every `unsafe` block/fn/impl needs an adjacent `// SAFETY:`
/// comment (or a `# Safety` doc section directly above). Emits the
/// full inventory of unsafe sites either way.
pub fn unsafe_audit(files: &[SourceFile], out: &mut Collector, inventory: &mut Vec<UnsafeSite>) {
    for file in files {
        for (li, line) in file.lines.iter().enumerate() {
            if line.in_test || !has_token(&line.code, "unsafe") {
                continue;
            }
            let kind = if has_token(&line.code, "unsafe impl") {
                "impl"
            } else if has_token(&line.code, "unsafe fn") {
                "fn"
            } else {
                "block"
            };
            let has_safety = safety_nearby(file, li);
            inventory.push(UnsafeSite {
                path: file.path.clone(),
                line: line.number,
                kind: kind.to_string(),
                has_safety,
            });
            if !has_safety {
                out.emit(
                    file,
                    UNSAFE_AUDIT,
                    line.number,
                    format!("`unsafe` {kind} without an adjacent `// SAFETY:` comment"),
                );
            }
        }
    }
}

/// A SAFETY comment counts when it sits on the unsafe line itself or on
/// a directly preceding run of comment/attribute/blank lines.
fn safety_nearby(file: &SourceFile, li: usize) -> bool {
    let has = |idx: usize| {
        let c = &file.lines[idx].comment;
        c.contains("SAFETY:") || c.contains("# Safety")
    };
    if has(li) {
        return true;
    }
    let mut k = li;
    while k > 0 {
        k -= 1;
        let code = file.lines[k].code.trim();
        if !(code.is_empty() || code.starts_with("#[")) {
            return false;
        }
        if has(k) {
            return true;
        }
    }
    false
}

// ---------------------------------------------------------------- L4 --

/// File/dir-prefix allowlist (entries ending in `/` match as prefixes).
pub struct PathAllowlist {
    entries: Vec<String>,
}

impl PathAllowlist {
    pub fn parse(text: &str) -> Self {
        let entries = text
            .lines()
            .map(|l| l.split('#').next().unwrap_or("").trim().to_string())
            .filter(|l| !l.is_empty())
            .collect();
        PathAllowlist { entries }
    }

    pub fn matches(&self, path: &str) -> bool {
        self.entries
            .iter()
            .any(|e| if e.ends_with('/') { path.starts_with(e.as_str()) } else { path == e })
    }
}

/// L4: `println!`/`eprintln!` are reserved for `telemetry/diag.rs` and
/// the explicit allowlist (the CLI binary and experiment drivers).
pub fn diag_hygiene(files: &[SourceFile], allow: &PathAllowlist, out: &mut Collector) {
    for file in files {
        if file.path == "rust/src/telemetry/diag.rs" || allow.matches(&file.path) {
            continue;
        }
        for line in &file.lines {
            if line.in_test {
                continue;
            }
            for tok in ["println!", "eprintln!"] {
                if has_token(&line.code, tok) {
                    out.emit(
                        file,
                        DIAG_HYGIENE,
                        line.number,
                        format!(
                            "`{tok}` outside telemetry/diag.rs — route through telemetry::diag \
                             or add the file to rust/lint/print_allow.txt"
                        ),
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------- L5 --

/// The library decode/read surface the panic policy covers.
const PANIC_SCOPE_DIRS: [&str; 6] = [
    "rust/src/store/",
    "rust/src/codec/",
    "rust/src/correction/",
    "rust/src/encoding/",
    "rust/src/compressors/",
    "rust/src/server/",
];
const PANIC_SCOPE_FILES: [&str; 1] = ["rust/src/data/io.rs"];

pub fn in_panic_scope(path: &str) -> bool {
    PANIC_SCOPE_DIRS.iter().any(|d| path.starts_with(d))
        || PANIC_SCOPE_FILES.iter().any(|f| path == *f)
}

/// One `path count` row of `rust/lint/panic_allow.txt`.
pub struct PanicAllowEntry {
    pub path: String,
    pub count: usize,
    /// 1-based line in the allowlist file.
    pub line: usize,
}

pub fn parse_panic_allowlist(
    text: &str,
    allow_path: &str,
    out: &mut Collector,
) -> Vec<PanicAllowEntry> {
    let mut entries = Vec::new();
    for (idx, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut it = line.split_whitespace();
        let (Some(path), Some(count), None) = (it.next(), it.next(), it.next()) else {
            out.emit_at(
                LINT_CONFIG,
                allow_path,
                idx + 1,
                format!("malformed allowlist row `{raw}` (expected `path count`)"),
            );
            continue;
        };
        let Ok(count) = count.parse::<usize>() else {
            out.emit_at(
                LINT_CONFIG,
                allow_path,
                idx + 1,
                format!("malformed allowlist count in `{raw}`"),
            );
            continue;
        };
        entries.push(PanicAllowEntry {
            path: path.to_string(),
            count,
            line: idx + 1,
        });
    }
    entries
}

/// L5: count `.unwrap()` / `.expect(` occurrences per in-scope file and
/// ratchet them against the checked-in allowlist — more than allowed is
/// a violation, fewer is a stale entry, so every regression and every
/// improvement shows up as a diff.
pub fn panic_policy(
    files: &[SourceFile],
    allow: &[PanicAllowEntry],
    allow_path: &str,
    out: &mut Collector,
) {
    for file in files {
        if !in_panic_scope(&file.path) {
            continue;
        }
        let mut sites: Vec<usize> = Vec::new();
        for line in &file.lines {
            if line.in_test {
                continue;
            }
            for tok in [".unwrap()", ".expect("] {
                for _ in find_token(&line.code, tok) {
                    if file.is_suppressed(PANIC_POLICY, line.number) {
                        out.suppressed += 1;
                    } else {
                        sites.push(line.number);
                    }
                }
            }
        }
        let allowed = allow.iter().find(|e| e.path == file.path);
        let budget = allowed.map_or(0, |e| e.count);
        if sites.len() > budget {
            out.emit_at(
                PANIC_POLICY,
                &file.path,
                sites[0],
                format!(
                    "{} unwrap()/expect() call(s) in a decode/read path (lines {:?}) but {} \
                     allows {budget} — return Result errors instead, or raise the allowlist \
                     entry with justification",
                    sites.len(),
                    sites,
                    allow_path,
                ),
            );
        } else if sites.len() < budget {
            let entry = allowed.expect("budget > 0 implies an entry");
            out.emit_at(
                PANIC_POLICY,
                allow_path,
                entry.line,
                format!(
                    "stale allowlist entry: `{}` allows {budget} panic site(s) but only {} \
                     remain — ratchet the count down",
                    file.path,
                    sites.len(),
                ),
            );
        }
    }
    for entry in allow {
        if !files.iter().any(|f| f.path == entry.path) {
            out.emit_at(
                PANIC_POLICY,
                allow_path,
                entry.line,
                format!(
                    "stale allowlist entry: `{}` does not name a scanned source file",
                    entry.path
                ),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::scan_str;

    fn glossary(doc: &str) -> TelemetryGlossary {
        crate::docparse::telemetry_glossary(doc)
    }

    const DOC: &str = "\
### Span-name glossary

| span | where |
|---|---|
| `op.run` | x |

## Metric-name glossary

| name | kind |
|---|---|
| `op.items` | C |
| `cache.plan.{a,b}.hits/misses` | C |
";

    #[test]
    fn l1_accepts_documented_names_and_patterns() {
        let files = [scan_str(
            "rust/src/x.rs",
            "fn f() {\n    telemetry::counter(\"op.items\").add(1);\n    let _s = telemetry::span(\"op.run\");\n    let m = |k: &str| format!(\"cache.plan.{n}.{k}\");\n}\n",
        )];
        let mut col = Collector::new();
        telemetry_drift(&files, &glossary(DOC), "DOC", &mut col);
        assert!(col.findings.is_empty(), "{:?}", col.findings);
    }

    #[test]
    fn l1_flags_undocumented_code_names_and_uncoded_doc_names() {
        let files = [scan_str(
            "rust/src/x.rs",
            "fn f() {\n    telemetry::counter(\"op.items\").add(1);\n    telemetry::counter(\"rogue.metric\").add(1);\n}\n",
        )];
        let mut col = Collector::new();
        telemetry_drift(&files, &glossary(DOC), "DOC", &mut col);
        let msgs: Vec<&str> = col.findings.iter().map(|f| f.message.as_str()).collect();
        assert!(msgs.iter().any(|m| m.contains("`rogue.metric`")), "{msgs:?}");
        // op.run plus the four expanded cache.plan.* names are
        // documented but never constructed.
        assert_eq!(
            col.findings.iter().filter(|f| f.message.contains("never constructed")).count(),
            5,
            "{msgs:?}"
        );
    }

    #[test]
    fn l1_ignores_test_code_and_unshaped_literals() {
        let files = [scan_str(
            "rust/src/x.rs",
            "fn f() {\n    let _ = format!(\"{}.ffcz\", stem);\n}\n#[cfg(test)]\nmod tests {\n    fn t() { telemetry::counter(\"test.only.name\").add(1); }\n}\n",
        )];
        let mut col = Collector::new();
        telemetry_drift(&files, &glossary(DOC), "DOC", &mut col);
        assert!(!col
            .findings
            .iter()
            .any(|f| f.message.contains("test.only.name") || f.message.contains("ffcz")));
    }

    #[test]
    fn l2_matches_values_across_radix_and_byte_strings() {
        let files = [scan_str(
            "rust/src/c.rs",
            "pub const MAGIC: &[u8; 4] = b\"ABCD\";\npub const FLAG: u8 = 0b0000_0001;\npub const LEN: usize = 24;\n",
        )];
        let rows = crate::docparse::format_constants(
            "| `MAGIC` | `ABCD` |\n| `FLAG` | `0x01` |\n| `LEN` | `24` |\n",
        );
        let mut col = Collector::new();
        format_constants_rule(&files, &rows, "DOC", &mut col);
        assert!(col.findings.is_empty(), "{:?}", col.findings);
    }

    #[test]
    fn l2_flags_drifted_and_missing_constants() {
        let files = [scan_str("rust/src/c.rs", "pub const FLAG: u8 = 0x02;\n")];
        let rows =
            crate::docparse::format_constants("| `FLAG` | `0x01` |\n| `GONE` | `7` |\n");
        let mut col = Collector::new();
        format_constants_rule(&files, &rows, "DOC", &mut col);
        assert_eq!(col.findings.len(), 2, "{:?}", col.findings);
        assert!(col.findings.iter().any(|f| f.message.contains("`const FLAG`")));
        assert!(col.findings.iter().any(|f| f.message.contains("`GONE`")));
    }

    #[test]
    fn l3_requires_adjacent_safety_comments() {
        let ok = scan_str(
            "rust/src/u.rs",
            "// SAFETY: disjoint per the work split.\nunsafe { go() }\n\n/// # Safety\n/// caller upholds X\npub unsafe fn f() {}\n",
        );
        let bad = scan_str("rust/src/v.rs", "unsafe impl Send for P {}\n");
        let mut col = Collector::new();
        let mut inv = Vec::new();
        unsafe_audit(&[ok, bad], &mut col, &mut inv);
        assert_eq!(col.findings.len(), 1, "{:?}", col.findings);
        assert_eq!(col.findings[0].path, "rust/src/v.rs");
        assert!(col.findings[0].message.contains("impl"));
        assert_eq!(inv.len(), 3);
        assert_eq!(inv.iter().filter(|s| s.has_safety).count(), 2);
    }

    #[test]
    fn l3_suppression_silences_but_inventories() {
        let f = scan_str(
            "rust/src/u.rs",
            "// ffcz-lint: allow(unsafe-audit)\nunsafe { go() }\n",
        );
        let mut col = Collector::new();
        let mut inv = Vec::new();
        unsafe_audit(&[f], &mut col, &mut inv);
        assert!(col.findings.is_empty());
        assert_eq!(col.suppressed, 1);
        assert_eq!(inv.len(), 1);
        assert!(!inv[0].has_safety);
    }

    #[test]
    fn l4_flags_prints_outside_the_allowlist() {
        let files = [
            scan_str("rust/src/a.rs", "fn f() { println!(\"x\"); }\n"),
            scan_str("rust/src/main.rs", "fn main() { println!(\"x\"); }\n"),
            scan_str("rust/src/experiments/fig1.rs", "fn f() { eprintln!(\"x\"); }\n"),
            scan_str("rust/src/telemetry/diag.rs", "fn f() { eprintln!(\"x\"); }\n"),
        ];
        let allow = PathAllowlist::parse("rust/src/main.rs\nrust/src/experiments/ # drivers\n");
        let mut col = Collector::new();
        diag_hygiene(&files, &allow, &mut col);
        assert_eq!(col.findings.len(), 1, "{:?}", col.findings);
        assert_eq!(col.findings[0].path, "rust/src/a.rs");
    }

    #[test]
    fn l5_ratchets_in_both_directions() {
        let files = [
            scan_str(
                "rust/src/store/r.rs",
                "fn f() { a.unwrap(); b.expect(\"m\"); }\n",
            ),
            scan_str("rust/src/codec/d.rs", "fn g() { c.unwrap(); }\n"),
            scan_str("rust/src/fourier/out_of_scope.rs", "fn h() { d.unwrap(); }\n"),
        ];
        let mut col = Collector::new();
        let allow = parse_panic_allowlist(
            "rust/src/store/r.rs 2\nrust/src/codec/d.rs 3\n",
            "LIST",
            &mut col,
        );
        panic_policy(&files, &allow, "LIST", &mut col);
        // store/r.rs exactly meets its budget; codec/d.rs is stale
        // (allows 3, has 1); fourier is out of scope entirely.
        assert_eq!(col.findings.len(), 1, "{:?}", col.findings);
        assert!(col.findings[0].message.contains("stale"), "{:?}", col.findings);

        let mut col = Collector::new();
        panic_policy(&files, &[], "LIST", &mut col);
        // With no allowlist both in-scope files violate.
        assert_eq!(col.findings.len(), 2, "{:?}", col.findings);
        assert!(col.findings.iter().all(|f| f.message.contains("decode/read path")));
    }

    #[test]
    fn l5_inline_suppression_and_unwrap_or_are_exempt() {
        let files = [scan_str(
            "rust/src/store/r.rs",
            "fn f() {\n    a.unwrap_or(0);\n    b.unwrap(); // ffcz-lint: allow(panic-policy)\n}\n",
        )];
        let mut col = Collector::new();
        panic_policy(&files, &[], "LIST", &mut col);
        assert!(col.findings.is_empty(), "{:?}", col.findings);
        assert_eq!(col.suppressed, 1);
    }

    #[test]
    fn l5_flags_stale_paths() {
        let mut col = Collector::new();
        let allow = parse_panic_allowlist("rust/src/store/gone.rs 1\n", "LIST", &mut col);
        panic_policy(&[], &allow, "LIST", &mut col);
        assert_eq!(col.findings.len(), 1);
        assert!(col.findings[0].message.contains("does not name"));
    }

    #[test]
    fn pattern_matching_is_segment_wise() {
        assert!(pattern_matches("a.{x}.c", "a.b.c"));
        assert!(!pattern_matches("a.{x}.c", "a.b.d"));
        assert!(!pattern_matches("a.{x}.c", "a.b.c.d"));
        assert!(is_pattern_shaped("fourier.plan_cache.{name}.{kind}"));
        assert!(!is_pattern_shaped("{}.ffcz"));
        assert!(!is_pattern_shaped("creating {}"));
    }
}
