//! A minimal lexical model of Rust source, hand-rolled in the spirit of
//! the crate's `util/json.rs`: no `syn`, no proc-macro machinery — one
//! pass that classifies every character as code, comment, or literal,
//! which is exactly the fidelity the ffcz-lint rules need (token
//! presence, string-literal extraction, brace depth, `#[cfg(test)]`
//! regions, suppression comments).

use std::collections::HashMap;

/// One physical line of a scanned source file.
#[derive(Debug, Default)]
pub struct Line {
    /// 1-based line number.
    pub number: usize,
    /// Code with comments removed and string/char literal contents
    /// blanked. The delimiters remain, so tokens such as `.expect(` and
    /// brace counts survive unchanged while literal contents can never
    /// fake a token match.
    pub code: String,
    /// Contents of string literals that *close* on this line.
    pub strings: Vec<String>,
    /// Comment text on this line (line comments and block-comment
    /// fragments, markers kept).
    pub comment: String,
    /// True when the line sits inside a `#[cfg(test)]` item.
    pub in_test: bool,
}

/// A scanned source file: the unit every lint rule consumes.
pub struct SourceFile {
    /// Repo-root-relative path with forward slashes.
    pub path: String,
    pub lines: Vec<Line>,
    /// Line number → rules suppressed on that line via
    /// `// ffcz-lint: allow(<rule>, …)`.
    suppressions: HashMap<usize, Vec<String>>,
}

impl SourceFile {
    /// Whether `rule` findings are suppressed on `line` (1-based). A
    /// suppression comment on its own line applies to the next line
    /// that carries code; `allow(all)` suppresses every rule.
    pub fn is_suppressed(&self, rule: &str, line: usize) -> bool {
        self.suppressions
            .get(&line)
            .is_some_and(|rs| rs.iter().any(|r| r == rule || r == "all"))
    }
}

enum Mode {
    Code,
    LineComment,
    BlockComment(u32),
    Str { raw_hashes: Option<u32>, buf: String },
}

/// Scan source text into the line model. `path` is carried through
/// verbatim for findings.
pub fn scan_str(path: &str, text: &str) -> SourceFile {
    let chars: Vec<char> = text.chars().collect();
    let mut lines: Vec<Line> = Vec::new();
    let mut cur = Line {
        number: 1,
        ..Line::default()
    };
    let mut mode = Mode::Code;
    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            match &mut mode {
                Mode::LineComment => mode = Mode::Code,
                Mode::Str { buf, .. } => buf.push('\n'),
                _ => {}
            }
            let number = cur.number;
            lines.push(std::mem::take(&mut cur));
            cur.number = number + 1;
            i += 1;
            continue;
        }
        match &mut mode {
            Mode::Code => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    mode = Mode::LineComment;
                    cur.comment.push_str("//");
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    mode = Mode::BlockComment(1);
                    cur.comment.push_str("/*");
                    i += 2;
                } else if c == '"' {
                    let raw_hashes = raw_prefix(&cur.code);
                    cur.code.push('"');
                    mode = Mode::Str {
                        raw_hashes,
                        buf: String::new(),
                    };
                    i += 1;
                } else if c == '\'' {
                    i = consume_quote(&chars, i, &mut cur.code);
                } else {
                    cur.code.push(c);
                    i += 1;
                }
            }
            Mode::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            Mode::BlockComment(depth) => {
                if c == '*' && chars.get(i + 1) == Some(&'/') {
                    *depth -= 1;
                    cur.comment.push_str("*/");
                    if *depth == 0 {
                        mode = Mode::Code;
                    }
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    *depth += 1;
                    cur.comment.push_str("/*");
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            Mode::Str { raw_hashes, buf } => match *raw_hashes {
                None => {
                    if c == '\\' {
                        buf.push(c);
                        if let Some(&next) = chars.get(i + 1) {
                            buf.push(next);
                        }
                        i += 2;
                    } else if c == '"' {
                        cur.code.push('"');
                        cur.strings.push(std::mem::take(buf));
                        mode = Mode::Code;
                        i += 1;
                    } else {
                        buf.push(c);
                        i += 1;
                    }
                }
                Some(h) => {
                    if c == '"' && (1..=h as usize).all(|k| chars.get(i + k) == Some(&'#')) {
                        cur.code.push('"');
                        for _ in 0..h {
                            cur.code.push('#');
                        }
                        cur.strings.push(std::mem::take(buf));
                        mode = Mode::Code;
                        i += 1 + h as usize;
                    } else {
                        buf.push(c);
                        i += 1;
                    }
                }
            },
        }
    }
    if !cur.code.is_empty() || !cur.comment.is_empty() || !cur.strings.is_empty() {
        lines.push(cur);
    }
    mark_tests(&mut lines);
    let suppressions = collect_suppressions(&lines);
    SourceFile {
        path: path.to_string(),
        lines,
        suppressions,
    }
}

/// At an opening `"` in code position: was it preceded by a raw-string
/// prefix (`r`, `r#…`, `br`, `br#…`)? Returns the hash count when raw.
fn raw_prefix(code: &str) -> Option<u32> {
    let mut it = code.chars().rev();
    let mut hashes = 0u32;
    let mut c = it.next();
    while c == Some('#') {
        hashes += 1;
        c = it.next();
    }
    if c == Some('r') {
        // An identifier ending in `r` (or `br`) followed by `"` is not
        // valid Rust, but keep the boundary check anyway.
        let prev = it.next();
        let prev = if prev == Some('b') { it.next() } else { prev };
        if !prev.is_some_and(is_word) {
            return Some(hashes);
        }
    }
    None
}

/// At a `'` in code position: consume a char literal (blanked to `''`
/// in `code`) or pass a lifetime tick through. Returns the next index.
fn consume_quote(chars: &[char], i: usize, code: &mut String) -> usize {
    if chars.get(i + 1) == Some(&'\\') {
        // Escaped char literal: the designator decides the body length
        // (`'\n'`, `'\''`, `'\x7F'`, `'\u{1F600}'`).
        let designator = chars.get(i + 2).copied().unwrap_or('\'');
        let mut j = i + 3;
        match designator {
            'x' => j += 2,
            'u' => {
                while j < chars.len() && chars[j] != '}' {
                    j += 1;
                }
                j += 1;
            }
            _ => {}
        }
        if chars.get(j) == Some(&'\'') {
            j += 1;
        }
        code.push_str("''");
        j
    } else if chars.get(i + 2) == Some(&'\'') && chars.get(i + 1) != Some(&'\'') {
        // Plain one-char literal, e.g. `'{'` — blanked so stray braces
        // in char literals cannot skew brace depth.
        code.push_str("''");
        i + 3
    } else {
        // A lifetime tick (`&'a str`).
        code.push('\'');
        i + 1
    }
}

const CFG_TEST: &str = "#[cfg(test)]";

/// Mark lines inside `#[cfg(test)]` items by tracking brace depth. The
/// attribute arms a pending flag; the next `{` opens the test region
/// (closed when depth returns to its level) and a `;` first means the
/// attribute applied to a braceless item (a `use`, say).
fn mark_tests(lines: &mut [Line]) {
    let mut depth: i32 = 0;
    let mut pending = false;
    let mut test_until: Option<i32> = None;
    for line in lines.iter_mut() {
        let attr_end = line.code.find(CFG_TEST).map(|p| p + CFG_TEST.len());
        if attr_end.is_some() {
            pending = true;
        }
        let mut in_test = test_until.is_some() || attr_end.is_some();
        for (bi, ch) in line.code.char_indices() {
            // An attribute later on this same line is not yet armed for
            // braces that precede it.
            let armed = pending && attr_end.map_or(true, |e| bi >= e);
            match ch {
                '{' => {
                    if armed && test_until.is_none() {
                        test_until = Some(depth);
                        pending = false;
                        in_test = true;
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if let Some(d) = test_until {
                        if depth <= d {
                            test_until = None;
                        }
                    }
                }
                ';' => {
                    if armed && test_until.is_none() {
                        pending = false;
                        in_test = true;
                    }
                }
                _ => {}
            }
        }
        line.in_test = in_test || test_until.is_some();
    }
}

fn collect_suppressions(lines: &[Line]) -> HashMap<usize, Vec<String>> {
    let mut map: HashMap<usize, Vec<String>> = HashMap::new();
    for (idx, line) in lines.iter().enumerate() {
        let Some(pos) = line.comment.find("ffcz-lint:") else {
            continue;
        };
        let rest = &line.comment[pos + "ffcz-lint:".len()..];
        let Some(open) = rest.find("allow(") else {
            continue;
        };
        let body = &rest[open + "allow(".len()..];
        let Some(close) = body.find(')') else {
            continue;
        };
        let rules: Vec<String> = body[..close]
            .split(',')
            .map(|r| r.trim().to_string())
            .filter(|r| !r.is_empty())
            .collect();
        if rules.is_empty() {
            continue;
        }
        // A comment-only line suppresses the next line that has code.
        let mut target = line.number;
        if line.code.trim().is_empty() {
            if let Some(next) = lines[idx + 1..].iter().find(|l| !l.code.trim().is_empty()) {
                target = next.number;
            }
        }
        map.entry(target).or_default().extend(rules);
    }
    map
}

pub(crate) fn is_word(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Byte offsets of word-boundary-respecting occurrences of `token` in
/// `code`. Boundaries are only enforced on the token ends that are
/// word characters, so `.expect(` matches after any receiver while
/// `println!` refuses to match inside `eprintln!`.
pub fn find_token(code: &str, token: &str) -> Vec<usize> {
    let lead = token.chars().next().is_some_and(is_word);
    let tail = token.chars().last().is_some_and(is_word);
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(rel) = code[from..].find(token) {
        let at = from + rel;
        let end = at + token.len();
        let before_ok = !lead || !code[..at].chars().next_back().is_some_and(is_word);
        let after_ok = !tail || !code[end..].chars().next().is_some_and(is_word);
        if before_ok && after_ok {
            out.push(at);
        }
        from = end;
    }
    out
}

pub fn has_token(code: &str, token: &str) -> bool {
    !find_token(code, token).is_empty()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_are_stripped_from_code() {
        let f = scan_str(
            "t.rs",
            "let x = \"counter(\\\"a.b\\\")\"; // println!(\"hi\")\n/* unsafe */ let y = 1;\n",
        );
        assert!(!f.lines[0].code.contains("counter"));
        assert_eq!(f.lines[0].strings, ["counter(\\\"a.b\\\")"]);
        assert!(f.lines[0].comment.contains("println!"));
        assert!(!f.lines[0].code.contains("println"));
        assert!(!f.lines[1].code.contains("unsafe"));
        assert!(f.lines[1].code.contains("let y = 1;"));
    }

    #[test]
    fn raw_strings_and_char_literals() {
        let f = scan_str(
            "t.rs",
            "let a = r#\"un\"safe\"#;\nlet b = '{';\nlet c: &'static str = \"x\";\nlet d = '\\'';\n",
        );
        assert_eq!(f.lines[0].strings, ["un\"safe"]);
        assert!(!f.lines[0].code.contains("safe"));
        // Char-literal contents are blanked so brace depth stays true.
        assert!(!f.lines[1].code.contains('{'));
        // Lifetimes survive as plain code.
        assert!(f.lines[2].code.contains("&'static str"));
        assert!(f.lines[3].code.contains("''"));
    }

    #[test]
    fn multiline_and_nested_block_comments() {
        let f = scan_str("t.rs", "a /* one /* two */ still */ b\n/* open\nunsafe {\n*/ c\n");
        assert_eq!(f.lines[0].code.trim(), "a  b");
        assert!(f.lines[2].code.is_empty());
        assert!(f.lines[2].comment.contains("unsafe"));
        assert_eq!(f.lines[3].code.trim(), "c");
    }

    #[test]
    fn cfg_test_regions_are_marked() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\nfn after() {}\n";
        let f = scan_str("t.rs", src);
        assert!(!f.lines[0].in_test);
        assert!(f.lines[1].in_test);
        assert!(f.lines[2].in_test);
        assert!(f.lines[3].in_test);
        assert!(f.lines[4].in_test);
        assert!(!f.lines[5].in_test);
    }

    #[test]
    fn cfg_test_on_a_braceless_item() {
        let src = "#[cfg(test)]\nuse std::fmt;\nfn live() { body(); }\n";
        let f = scan_str("t.rs", src);
        assert!(f.lines[1].in_test);
        assert!(!f.lines[2].in_test);
    }

    #[test]
    fn suppressions_attach_to_their_line_or_the_next_code_line() {
        let src = "a.unwrap(); // ffcz-lint: allow(panic-policy)\n\
                   // ffcz-lint: allow(unsafe-audit, diag-hygiene)\n\
                   // explanatory second line\n\
                   unsafe { boo() }\n\
                   b.unwrap();\n";
        let f = scan_str("t.rs", src);
        assert!(f.is_suppressed("panic-policy", 1));
        assert!(!f.is_suppressed("unsafe-audit", 1));
        assert!(f.is_suppressed("unsafe-audit", 4));
        assert!(f.is_suppressed("diag-hygiene", 4));
        assert!(!f.is_suppressed("panic-policy", 4));
        assert!(!f.is_suppressed("panic-policy", 5));
    }

    #[test]
    fn allow_all_suppresses_every_rule() {
        let f = scan_str("t.rs", "x.unwrap(); // ffcz-lint: allow(all)\n");
        assert!(f.is_suppressed("panic-policy", 1));
        assert!(f.is_suppressed("telemetry-drift", 1));
    }

    #[test]
    fn token_boundaries() {
        assert!(has_token("telemetry::counter(\"\")", "counter("));
        assert!(!has_token("chunk_counter(\"\")", "counter("));
        assert!(has_token("eprintln!(\"\")", "eprintln!"));
        assert!(!has_token("eprintln!(\"\")", "println!"));
        assert!(has_token("unsafe { }", "unsafe"));
        assert!(!has_token("unsafer()", "unsafe"));
        assert!(has_token("v.expect(\"m\")", ".expect("));
        assert!(!has_token("v.expect_err(\"m\")", ".expect("));
        assert!(has_token("v.unwrap()", ".unwrap()"));
        assert!(!has_token("v.unwrap_or(0)", ".unwrap()"));
    }
}
