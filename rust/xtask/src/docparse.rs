//! Parsers for the two normative documents the lint checks code
//! against: the `docs/FORMAT.md` § 1.2 constants table and the
//! `docs/TELEMETRY.md` span/metric glossaries. `rust/tests/format_doc.rs`
//! consumes [`format_constants`] too, so the doc-derived values have a
//! single source of truth.

/// One row of the FORMAT.md § 1.2 constants table.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DocConstant {
    pub name: String,
    pub value: String,
    /// 1-based line in the document.
    pub line: usize,
}

/// Extract the § 1.2 constants table: the only rows in the document
/// with exactly two backtick-quoted cells (`| \`NAME\` | \`VALUE\` |`).
pub fn format_constants(doc: &str) -> Vec<DocConstant> {
    let mut out = Vec::new();
    for (idx, line) in doc.lines().enumerate() {
        let cells: Vec<&str> = line.split('|').map(str::trim).collect();
        // "| `A` | `B` |" splits into ["", "`A`", "`B`", ""].
        if cells.len() == 4
            && cells[1].len() > 2
            && cells[1].starts_with('`')
            && cells[1].ends_with('`')
            && cells[2].len() > 2
            && cells[2].starts_with('`')
            && cells[2].ends_with('`')
        {
            out.push(DocConstant {
                name: cells[1].trim_matches('`').to_string(),
                value: cells[2].trim_matches('`').to_string(),
                line: idx + 1,
            });
        }
    }
    out
}

/// One documented telemetry name, fully expanded.
#[derive(Debug, Clone)]
pub struct DocName {
    pub name: String,
    /// 1-based line of the glossary row it expanded from.
    pub line: usize,
}

/// The TELEMETRY.md glossaries: span names and metric names, with
/// `{a,b,c}` brace sets and trailing `x/y/z` alternatives expanded.
#[derive(Debug, Default)]
pub struct TelemetryGlossary {
    pub spans: Vec<DocName>,
    pub metrics: Vec<DocName>,
}

impl TelemetryGlossary {
    pub fn all(&self) -> impl Iterator<Item = &DocName> {
        self.spans.iter().chain(self.metrics.iter())
    }
}

/// Parse the two glossary tables. A table row belongs to whichever
/// glossary the nearest preceding heading names; every backticked token
/// in the row's first cell is a (possibly compound) name.
pub fn telemetry_glossary(doc: &str) -> TelemetryGlossary {
    let mut out = TelemetryGlossary::default();
    let mut section = Section::None;
    for (idx, line) in doc.lines().enumerate() {
        if line.starts_with('#') {
            section = if line.contains("Span-name glossary") {
                Section::Spans
            } else if line.contains("Metric-name glossary") {
                Section::Metrics
            } else {
                Section::None
            };
            continue;
        }
        if !line.trim_start().starts_with('|') {
            continue;
        }
        let dest = match section {
            Section::Spans => &mut out.spans,
            Section::Metrics => &mut out.metrics,
            Section::None => continue,
        };
        let first_cell = line.trim_start().trim_start_matches('|');
        let first_cell = first_cell.split('|').next().unwrap_or("");
        for token in backticked(first_cell) {
            for name in expand_name(&token) {
                dest.push(DocName {
                    name,
                    line: idx + 1,
                });
            }
        }
    }
    out
}

enum Section {
    None,
    Spans,
    Metrics,
}

/// All `` `…` `` spans in a table cell.
fn backticked(cell: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = cell;
    while let Some(open) = rest.find('`') {
        let after = &rest[open + 1..];
        let Some(close) = after.find('`') else { break };
        if close > 0 {
            out.push(after[..close].to_string());
        }
        rest = &after[close + 1..];
    }
    out
}

/// Expand one glossary token into concrete names: first `{a,b,c}`
/// brace sets (`diag.messages.{error,warn}` → two names), then
/// `prefix.x/y/z` slash alternatives on the final segment
/// (`….fft.hits/misses` → `….fft.hits`, `….fft.misses`).
pub fn expand_name(token: &str) -> Vec<String> {
    expand_braces(token)
        .iter()
        .flat_map(|n| expand_slashes(n))
        .collect()
}

fn expand_braces(s: &str) -> Vec<String> {
    if let Some(open) = s.find('{') {
        if let Some(rel) = s[open..].find('}') {
            let close = open + rel;
            let inner = &s[open + 1..close];
            if inner.contains(',') {
                let mut out = Vec::new();
                for alt in inner.split(',') {
                    let expanded = format!("{}{}{}", &s[..open], alt.trim(), &s[close + 1..]);
                    out.extend(expand_braces(&expanded));
                }
                return out;
            }
        }
    }
    vec![s.to_string()]
}

fn expand_slashes(s: &str) -> Vec<String> {
    if !s.contains('/') {
        return vec![s.to_string()];
    }
    let mut parts = s.split('/');
    let head = parts.next().unwrap_or("");
    let prefix = match head.rfind('.') {
        Some(dot) => &head[..=dot],
        None => "",
    };
    let mut out = vec![head.to_string()];
    for alt in parts {
        out.push(format!("{prefix}{alt}"));
    }
    out
}

/// Shape filter for concrete telemetry names: lowercase/digit/underscore
/// segments joined by dots, at least two segments, at least one letter.
/// This is what separates a metric name from an ordinary string literal
/// that happens to sit on a telemetry-calling line.
pub fn is_metric_shaped(s: &str) -> bool {
    let mut has_alpha = false;
    let mut segments = 0;
    for seg in s.split('.') {
        if seg.is_empty() {
            return false;
        }
        for c in seg.chars() {
            if c.is_ascii_lowercase() {
                has_alpha = true;
            } else if !c.is_ascii_digit() && c != '_' {
                return false;
            }
        }
        segments += 1;
    }
    segments >= 2 && has_alpha
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants_table_rows_parse_with_lines() {
        let doc = "intro\n| constant | value |\n|---|---|\n| `MAGIC` | `ABCD` |\n| `VER` | `2` |\nnot | a | row\n";
        let rows = format_constants(doc);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].name, "MAGIC");
        assert_eq!(rows[0].value, "ABCD");
        assert_eq!(rows[0].line, 4);
        assert_eq!(rows[1].name, "VER");
        assert_eq!(rows[1].value, "2");
    }

    #[test]
    fn glossaries_split_by_heading_and_expand() {
        let doc = "\
### Span-name glossary

| span | where |
|---|---|
| `a.b` | x |
| `p.run` / `p.store` | y |

## Metric-name glossary

| name | kind |
|---|---|
| `m.{x,y}.hits/misses` | C |

## Other

| `ignored.name` | z |
";
        let g = telemetry_glossary(doc);
        let spans: Vec<&str> = g.spans.iter().map(|d| d.name.as_str()).collect();
        assert_eq!(spans, ["a.b", "p.run", "p.store"]);
        let metrics: Vec<&str> = g.metrics.iter().map(|d| d.name.as_str()).collect();
        assert_eq!(metrics, ["m.x.hits", "m.x.misses", "m.y.hits", "m.y.misses"]);
    }

    #[test]
    fn expansion_covers_braces_and_slash_alternatives() {
        assert_eq!(
            expand_name("fourier.plan_cache.{fft,rfft}.hits/misses/evictions"),
            [
                "fourier.plan_cache.fft.hits",
                "fourier.plan_cache.fft.misses",
                "fourier.plan_cache.fft.evictions",
                "fourier.plan_cache.rfft.hits",
                "fourier.plan_cache.rfft.misses",
                "fourier.plan_cache.rfft.evictions",
            ]
        );
        assert_eq!(expand_name("plain.name"), ["plain.name"]);
    }

    #[test]
    fn metric_shape_filter() {
        assert!(is_metric_shaped("store.encode.chunks"));
        assert!(is_metric_shaped("store.chunk.pocs_correct"));
        assert!(!is_metric_shaped("no_dots"));
        assert!(!is_metric_shaped("Has.Upper"));
        assert!(!is_metric_shaped("spaced out.name"));
        assert!(!is_metric_shaped("trailing.dot."));
        assert!(!is_metric_shaped("1.5"));
    }
}
